//! The unified batched sparse execution core: **one** select/forward path
//! shared by training and serving.
//!
//! Before this module the repo had two parallel implementations of
//! "select the active sets for a batch, then fire them": the training
//! selector's private batching (`sampling::lsh_select` densified and
//! hashed its own `B × L` fingerprint plane) and the serving engine's
//! per-request loop (`serve::engine` hashed and probed each request of a
//! micro-batch independently). Both re-implemented the same three steps —
//! densify, fingerprint-hash, probe/rank — against different table
//! owners, and the serving side gave back a large slice of the paper's
//! multiplication win by paying queue-amortized batching but per-request
//! hashing. This module collapses the two paths:
//!
//! * [`TableView`] — the table-backend abstraction. Implemented by the
//!   live, mutable [`LayerTables`] the trainer maintains, and by
//!   [`FrozenTableView`] (an immutable [`FrozenLayerTables`] epoch from
//!   the publish slot plus its per-worker scratch). The two backends keep
//!   their historical RNG contracts: training draws crowded-bucket /
//!   fallback randomness from the caller's RNG stream (per-example
//!   reproducibility), serving derives it from the query's own
//!   fingerprints (worker-order independence).
//! * [`select_batch_into`] — one-pass selection for a whole batch:
//!   densify every input, hash **all** `B × L` fingerprints in a single
//!   traversal of the projection data ([`TableView::hash_batch`] — one
//!   *fingerprint hash invocation* per layer per batch), then probe +
//!   rank + optionally §5.4-re-rank each sample over reused buffers.
//! * [`SparseBatchPlan`] — the product of selection: per-layer per-sample
//!   active sets plus the deduplicated **union** of each layer's active
//!   ids (first-touch order — the same sequence the gradient sinks touch,
//!   which is what makes batch-amortized LSH maintenance correct).
//! * [`BatchExecutor`] — the serving-side driver: builds the plan layer
//!   by layer and runs the fused sparse forward over it (per-sample
//!   multiplication attribution preserved, so a response's `mults` is
//!   identical to what per-request execution reported).
//!
//! **Accounting vocabulary:** a *fingerprint hash invocation* is one call
//! into the one-pass batched hashing routine — it covers every co-batched
//! sample for one layer. Per-request execution of a micro-batch of `B`
//! requests costs `B × hidden_layers` invocations; fused execution costs
//! `hidden_layers`. The *multiplication* count per sample (K·L·(d+1)
//! hashing + sparse forward + optional re-rank) is unchanged — the
//! invocation count is the unit the serve bench pins, because it is what
//! one-pass hashing actually amortizes (projection-plane traversals and
//! their memory traffic), counted, not timed.
//!
//! **Equivalence contract:** for the same inputs and table state, the
//! batched path produces bit-for-bit the active sets, activations and
//! logits of per-sample execution, in both backends. Pinned by the unit
//! tests below, `sampling::lsh_select` tests (training) and
//! `tests/serve.rs` / `serve::engine` tests (serving).

use crate::lsh::family::LshFamily;
use crate::lsh::frozen::{FrozenLayerTables, FrozenQueryScratch};
use crate::lsh::layered::{LayerTables, LshConfig};
use crate::lsh::sharded::{ShardedFrozenTables, ShardedLayerTables};
use crate::nn::layer::Layer;
use crate::nn::sparse::{LayerInput, SparseVec};
use crate::obs;
use crate::obs::{HealthTally, Stage};
use crate::sampling::{budget, rerank_exact};
use crate::train::metrics::MultCounters;
use crate::util::rng::Pcg64;

/// Densify a layer input into a pre-sized buffer of length `n_in`.
pub fn densify_into(input: LayerInput<'_>, buf: &mut [f32]) {
    match input {
        LayerInput::Dense(x) => buf.copy_from_slice(x),
        LayerInput::Sparse(s) => {
            buf.iter_mut().for_each(|v| *v = 0.0);
            for (i, v) in s.iter() {
                buf[i as usize] = v;
            }
        }
    }
}

/// One per-layer table backend the shared execution core can select
/// through. See the module docs for the two implementations and their
/// RNG contracts.
pub trait TableView {
    /// The (K, L, probes, …) operating point of this table stack.
    fn lsh_config(&self) -> LshConfig;

    /// Number of nodes (neurons) the tables index.
    fn nodes(&self) -> usize;

    /// Fingerprint words per sample in the batch fingerprint plane.
    /// `L` for a single table stack; sharded backends interleave one
    /// `L`-wide group per shard (`S × L`), since every shard hashes with
    /// its own family.
    fn fps_width(&self) -> usize {
        self.lsh_config().l
    }

    /// One-pass fingerprint hashing of a whole batch: `q_plane` holds
    /// `bsz` densified queries of width `n_in`, `fps_plane` receives
    /// `bsz × L` fingerprints (row-major). One call = one *fingerprint
    /// hash invocation* (the unit the serve bench counts). Returns the
    /// per-sample hashing multiplication cost (K·L·(n_in+1), uniform
    /// across the batch).
    fn hash_batch(&mut self, q_plane: &[f32], n_in: usize, bsz: usize, fps_plane: &mut [u32])
        -> u64;

    /// Final active set for one prehashed sample: probe + rank, the
    /// optional §5.4 cheap re-rank at `rerank_factor`, and the backend's
    /// empty-result fallback. Returns the extra (re-rank)
    /// multiplications. `rng` is consumed only by the live training
    /// backend; the frozen backend derives its own from `fps`.
    #[allow(clippy::too_many_arguments)]
    fn select_prehashed(
        &mut self,
        layer: &Layer,
        q: &[f32],
        fps: &[u32],
        budget: usize,
        rerank_factor: usize,
        rng: &mut Pcg64,
        scored: &mut Vec<(f32, u32)>,
        out: &mut Vec<u32>,
    ) -> u64;

    /// The backend's table-health tally, if it keeps one. The shared
    /// selection path folds per-batch activation counts in through this
    /// — monitoring only, never consulted by selection itself.
    fn health(&self) -> Option<&HealthTally> {
        None
    }
}

/// Live training backend: the trainer's mutable table stack. Probe
/// randomness (crowded-bucket sub-sampling, empty-result fallback) comes
/// from the caller's RNG in sample order — the contract the batch-of-one
/// equivalence guarantee depends on.
impl TableView for LayerTables {
    fn lsh_config(&self) -> LshConfig {
        self.config()
    }

    fn nodes(&self) -> usize {
        self.n_nodes()
    }

    fn hash_batch(
        &mut self,
        q_plane: &[f32],
        n_in: usize,
        bsz: usize,
        fps_plane: &mut [u32],
    ) -> u64 {
        debug_assert_eq!(q_plane.len(), n_in * bsz);
        self.hash_query_batch(q_plane, bsz, fps_plane);
        let cfg = self.config();
        (cfg.k * cfg.l * (n_in + 1)) as u64
    }

    #[allow(clippy::too_many_arguments)]
    fn select_prehashed(
        &mut self,
        layer: &Layer,
        q: &[f32],
        fps: &[u32],
        budget: usize,
        rerank_factor: usize,
        rng: &mut Pcg64,
        scored: &mut Vec<(f32, u32)>,
        out: &mut Vec<u32>,
    ) -> u64 {
        let mut extra = 0u64;
        if rerank_factor > 1 {
            // Cheap re-ranking (§5.4): over-collect candidates, score them
            // exactly, keep the best `budget`.
            self.query_prehashed(fps, budget * rerank_factor, rng, out);
            extra += rerank_exact(layer, q, budget, out, scored);
        } else {
            self.query_prehashed(fps, budget, rng, out);
        }
        if out.is_empty() {
            // Hash miss (rare, small layers): fall back to random nodes so
            // training can proceed.
            out.extend(rng.sample_indices(layer.n_out(), budget.min(4)));
        }
        extra
    }

    fn health(&self) -> Option<&HealthTally> {
        Some(self.health_tally())
    }
}

/// Frozen serving backend: one immutable published table stack plus the
/// worker-private query scratch it probes through. Randomness is derived
/// from the query fingerprints (`lsh::frozen`), so identical requests get
/// identical active sets on any worker.
pub struct FrozenTableView<'a> {
    pub tables: &'a FrozenLayerTables,
    pub scratch: &'a mut FrozenQueryScratch,
}

impl TableView for FrozenTableView<'_> {
    fn lsh_config(&self) -> LshConfig {
        self.tables.config()
    }

    fn nodes(&self) -> usize {
        self.tables.n_nodes()
    }

    fn hash_batch(
        &mut self,
        q_plane: &[f32],
        n_in: usize,
        bsz: usize,
        fps_plane: &mut [u32],
    ) -> u64 {
        debug_assert_eq!(n_in, self.tables.family().dim());
        self.tables.family().hash_queries_batch(
            q_plane,
            bsz,
            &mut self.scratch.embed_plane,
            fps_plane,
        );
        self.tables.hash_mults()
    }

    #[allow(clippy::too_many_arguments)]
    fn select_prehashed(
        &mut self,
        layer: &Layer,
        q: &[f32],
        fps: &[u32],
        budget: usize,
        rerank_factor: usize,
        _rng: &mut Pcg64,
        scored: &mut Vec<(f32, u32)>,
        out: &mut Vec<u32>,
    ) -> u64 {
        out.clear();
        if budget == 0 || self.tables.n_nodes() == 0 {
            return 0;
        }
        let mut rng = self.tables.derived_rng(fps);
        // Over-collect when re-ranking; the frozen empty-result fallback
        // runs inside probe_prehashed at the *collection* budget — the
        // exact semantics the per-request engine had.
        let collect = if rerank_factor > 1 { budget * rerank_factor } else { budget };
        self.tables.probe_prehashed(fps, collect, &mut *self.scratch, &mut rng, out);
        if rerank_factor > 1 {
            rerank_exact(layer, q, budget, out, scored)
        } else {
            0
        }
    }

    fn health(&self) -> Option<&HealthTally> {
        Some(self.tables.health_tally())
    }
}

/// Live sharded training backend: per-shard table stacks over the
/// mirror of one wide layer. Hashes the batch once per shard (each
/// shard's own family), probes/ranks per shard under a proportional
/// budget split, and merges to global ids. Caller RNG is consumed in
/// shard order — at `S = 1` every call reduces bit-for-bit to the
/// unsharded [`LayerTables`] backend.
impl TableView for ShardedLayerTables {
    fn lsh_config(&self) -> LshConfig {
        self.config()
    }

    fn nodes(&self) -> usize {
        self.n_nodes()
    }

    fn fps_width(&self) -> usize {
        self.shard_count() * self.config().l
    }

    fn hash_batch(
        &mut self,
        q_plane: &[f32],
        n_in: usize,
        bsz: usize,
        fps_plane: &mut [u32],
    ) -> u64 {
        debug_assert_eq!(q_plane.len(), n_in * bsz);
        self.hash_batch_sharded(q_plane, bsz, fps_plane);
        let cfg = self.config();
        (self.shard_count() * cfg.k * cfg.l * (n_in + 1)) as u64
    }

    #[allow(clippy::too_many_arguments)]
    fn select_prehashed(
        &mut self,
        layer: &Layer,
        q: &[f32],
        fps: &[u32],
        budget: usize,
        rerank_factor: usize,
        rng: &mut Pcg64,
        scored: &mut Vec<(f32, u32)>,
        out: &mut Vec<u32>,
    ) -> u64 {
        let collect = if rerank_factor > 1 { rerank_factor } else { 1 };
        self.probe_prehashed_sharded(fps, budget, collect, rng, out);
        let mut extra = 0u64;
        if rerank_factor > 1 {
            // Global §5.4 re-rank over the merged candidates: the top
            // `budget` is picked across shards, not within them.
            extra += rerank_exact(layer, q, budget, out, scored);
        }
        if out.is_empty() {
            // Global hash-miss fallback, same as the unsharded backend.
            out.extend(rng.sample_indices(layer.n_out(), budget.min(4)));
        }
        extra
    }

    fn health(&self) -> Option<&HealthTally> {
        Some(self.health_tally())
    }
}

/// Frozen sharded serving backend: one immutable sharded stack plus one
/// per-shard query scratch. Randomness derives from the full
/// concatenated fingerprints (all shards), so at `S = 1` the derivation
/// — and everything after it — is exactly [`FrozenTableView`]'s.
pub struct ShardedFrozenView<'a> {
    stack: &'a ShardedFrozenTables,
    scratches: &'a mut [FrozenQueryScratch],
    budget_split: Vec<usize>,
}

impl<'a> ShardedFrozenView<'a> {
    /// `scratches` must hold exactly one scratch per shard.
    pub fn new(stack: &'a ShardedFrozenTables, scratches: &'a mut [FrozenQueryScratch]) -> Self {
        debug_assert_eq!(scratches.len(), stack.shard_count());
        ShardedFrozenView { stack, scratches, budget_split: Vec::new() }
    }
}

impl TableView for ShardedFrozenView<'_> {
    fn lsh_config(&self) -> LshConfig {
        self.stack.config()
    }

    fn nodes(&self) -> usize {
        self.stack.n_nodes()
    }

    fn fps_width(&self) -> usize {
        self.stack.shard_count() * self.stack.config().l
    }

    fn hash_batch(
        &mut self,
        q_plane: &[f32],
        n_in: usize,
        bsz: usize,
        fps_plane: &mut [u32],
    ) -> u64 {
        let l = self.stack.config().l;
        let s_count = self.stack.shard_count();
        debug_assert_eq!(fps_plane.len(), bsz * l * s_count);
        for (s, shard) in self.stack.shards().iter().enumerate() {
            debug_assert_eq!(n_in, shard.family().dim());
            let scratch = &mut self.scratches[s];
            scratch.fps_batch.clear();
            scratch.fps_batch.resize(bsz * l, 0);
            shard.family().hash_queries_batch(
                q_plane,
                bsz,
                &mut scratch.embed_plane,
                &mut scratch.fps_batch,
            );
            for b in 0..bsz {
                let dst = (b * s_count + s) * l;
                fps_plane[dst..dst + l].copy_from_slice(&scratch.fps_batch[b * l..(b + 1) * l]);
            }
        }
        self.stack.hash_mults()
    }

    #[allow(clippy::too_many_arguments)]
    fn select_prehashed(
        &mut self,
        layer: &Layer,
        q: &[f32],
        fps: &[u32],
        budget: usize,
        rerank_factor: usize,
        _rng: &mut Pcg64,
        scored: &mut Vec<(f32, u32)>,
        out: &mut Vec<u32>,
    ) -> u64 {
        out.clear();
        if budget == 0 || self.stack.n_nodes() == 0 {
            return 0;
        }
        let mut rng = self.stack.shards()[0].derived_rng(fps);
        let collect = if rerank_factor > 1 { rerank_factor } else { 1 };
        self.stack.probe_prehashed_sharded(
            fps,
            budget,
            collect,
            self.scratches,
            &mut self.budget_split,
            &mut rng,
            out,
        );
        if rerank_factor > 1 {
            rerank_exact(layer, q, budget, out, scored)
        } else {
            0
        }
    }

    fn health(&self) -> Option<&HealthTally> {
        Some(self.stack.health_tally())
    }
}

/// Either frozen backend, dispatched from a
/// [`crate::lsh::LayerTableStack`] — what the serving engine builds per
/// hidden layer so one executor call can mix sharded and single layers.
pub enum AnyFrozenView<'a> {
    Single(FrozenTableView<'a>),
    Sharded(ShardedFrozenView<'a>),
}

impl TableView for AnyFrozenView<'_> {
    fn lsh_config(&self) -> LshConfig {
        match self {
            AnyFrozenView::Single(v) => v.lsh_config(),
            AnyFrozenView::Sharded(v) => v.lsh_config(),
        }
    }

    fn nodes(&self) -> usize {
        match self {
            AnyFrozenView::Single(v) => v.nodes(),
            AnyFrozenView::Sharded(v) => v.nodes(),
        }
    }

    fn fps_width(&self) -> usize {
        match self {
            AnyFrozenView::Single(v) => v.fps_width(),
            AnyFrozenView::Sharded(v) => v.fps_width(),
        }
    }

    fn hash_batch(
        &mut self,
        q_plane: &[f32],
        n_in: usize,
        bsz: usize,
        fps_plane: &mut [u32],
    ) -> u64 {
        match self {
            AnyFrozenView::Single(v) => v.hash_batch(q_plane, n_in, bsz, fps_plane),
            AnyFrozenView::Sharded(v) => v.hash_batch(q_plane, n_in, bsz, fps_plane),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn select_prehashed(
        &mut self,
        layer: &Layer,
        q: &[f32],
        fps: &[u32],
        budget: usize,
        rerank_factor: usize,
        rng: &mut Pcg64,
        scored: &mut Vec<(f32, u32)>,
        out: &mut Vec<u32>,
    ) -> u64 {
        match self {
            AnyFrozenView::Single(v) => {
                v.select_prehashed(layer, q, fps, budget, rerank_factor, rng, scored, out)
            }
            AnyFrozenView::Sharded(v) => {
                v.select_prehashed(layer, q, fps, budget, rerank_factor, rng, scored, out)
            }
        }
    }

    fn health(&self) -> Option<&HealthTally> {
        match self {
            AnyFrozenView::Single(v) => v.health(),
            AnyFrozenView::Sharded(v) => v.health(),
        }
    }
}

/// Reusable buffers for one [`select_batch_into`] pass: the densified
/// query plane, the batch fingerprint plane and the re-rank scoring
/// buffer. Grown once, reused forever.
#[derive(Default)]
pub struct BatchSelectScratch {
    pub q_plane: Vec<f32>,
    pub fps_plane: Vec<u32>,
    pub scored: Vec<(f32, u32)>,
}

/// What one batched selection pass cost.
#[derive(Clone, Copy, Debug, Default)]
pub struct SelectStats {
    /// Total selection multiplications across the batch (hashing +
    /// optional re-rank), same accounting as per-sample selection.
    pub selection_mults: u64,
    /// Fingerprint hash invocations performed (always 1: the whole batch
    /// is hashed in one pass).
    pub hash_invocations: u64,
}

/// One-pass batched selection through any [`TableView`]: densify every
/// input, hash all fingerprints in a single invocation, then probe +
/// rank per sample in order. Fills `outs[s]` with sample `s`'s active
/// set and `per_sample_mults[s]` with its exact selection cost
/// (hashing + re-rank — the per-request attribution serving responses
/// report). Bit-for-bit identical to per-sample selection on the same
/// backend.
#[allow(clippy::too_many_arguments)]
pub fn select_batch_into<V: TableView>(
    view: &mut V,
    layer: &Layer,
    inputs: &[LayerInput<'_>],
    budget: usize,
    rerank_factor: usize,
    rng: &mut Pcg64,
    scratch: &mut BatchSelectScratch,
    per_sample_mults: &mut [u64],
    outs: &mut [Vec<u32>],
) -> SelectStats {
    let n = inputs.len();
    debug_assert_eq!(outs.len(), n);
    debug_assert_eq!(per_sample_mults.len(), n);
    let n_in = layer.n_in();
    let l = view.fps_width();
    // Phase 1: densify + hash the whole batch (resize reuses the buffer;
    // densify_into overwrites every queried cell).
    let span = obs::begin(Stage::Densify);
    scratch.q_plane.resize(n * n_in, 0.0);
    for (s, input) in inputs.iter().enumerate() {
        densify_into(*input, &mut scratch.q_plane[s * n_in..(s + 1) * n_in]);
    }
    obs::end(span);
    let span = obs::begin(Stage::HashFp);
    scratch.fps_plane.clear();
    scratch.fps_plane.resize(n * l, 0);
    let hash_per_sample = view.hash_batch(&scratch.q_plane, n_in, n, &mut scratch.fps_plane);
    obs::end(span);
    // Phase 2: probe + rank each sample over the shared scratch, in
    // sample order (the RNG-draw order the equivalence guarantee pins).
    let span = obs::begin(Stage::ProbeRank);
    let mut selection_mults = 0u64;
    for (s, out) in outs.iter_mut().enumerate() {
        let q = &scratch.q_plane[s * n_in..(s + 1) * n_in];
        let fps = &scratch.fps_plane[s * l..(s + 1) * l];
        let extra = view.select_prehashed(
            layer,
            q,
            fps,
            budget,
            rerank_factor,
            rng,
            &mut scratch.scored,
            out,
        );
        per_sample_mults[s] = hash_per_sample + extra;
        selection_mults += hash_per_sample + extra;
    }
    obs::end(span);
    // Table-health fold-in: pure reads of the just-computed active sets
    // plus relaxed counter writes — never feeds back into selection.
    if obs::enabled() {
        if let Some(h) = view.health() {
            h.note_batch(&*outs);
            if n > 0 && obs::recall_due() {
                obs::recall_probe(layer, &scratch.q_plane[..n_in], &outs[0], h);
            }
        }
    }
    SelectStats { selection_mults, hash_invocations: 1 }
}

/// One hidden layer's slice of a [`SparseBatchPlan`]: the per-sample
/// active sets, their deduplicated union, and the inverted (CSR) index
/// over the union that drives the union-major gather. Every buffer here
/// is reused across batches — `refresh_union` allocates only while a
/// batch is larger than any batch seen before.
#[derive(Default)]
pub struct LayerPlan {
    /// Per-sample active sets (index = sample; grown to the batch size,
    /// never shrunk).
    pub actives: Vec<Vec<u32>>,
    /// Distinct active ids across the batch, first-touch order (sample
    /// 0's set first). This is exactly the row sequence the trainer's
    /// gradient sinks register, so batch-amortized LSH maintenance over
    /// the union touches the same rows in the same order.
    union: Vec<u32>,
    /// Membership stamp per node (`stamp[i] == epoch` ⇒ already in the
    /// union) — dedup without a hash set, same trick as the table
    /// scratch.
    stamp: Vec<u32>,
    /// Union slot of node `i` (valid only when `stamp[i] == epoch`).
    slot: Vec<u32>,
    epoch: u32,
    /// CSR inverted index over the union: the batch members of union
    /// slot `u` are `members[row_starts[u]..row_starts[u + 1]]`, each
    /// packed as `(sample << 32) | position`, in (sample, position)
    /// order — so a row's first member is that node's first touch.
    row_starts: Vec<u32>,
    members: Vec<u64>,
    /// Fill cursor scratch for the CSR counting sort.
    cursor: Vec<u32>,
}

impl LayerPlan {
    /// The union of the batch's active sets (valid after
    /// [`LayerPlan::refresh_union`]).
    pub fn union(&self) -> &[u32] {
        &self.union
    }

    /// Recompute the union and its inverted index from `actives[..bsz]`.
    pub fn refresh_union(&mut self, n_out: usize, bsz: usize) {
        if self.stamp.len() < n_out {
            self.stamp.resize(n_out, 0);
            self.slot.resize(n_out, 0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // Stamp wrap: reset (once per 2^32 batches). Stamps reset to
            // 0, which the epoch counter never holds outside this branch,
            // so a stale stamp can never collide with a future epoch.
            self.stamp.iter_mut().for_each(|v| *v = 0);
            self.epoch = 1;
        }
        self.union.clear();
        let mut total = 0usize;
        for s in 0..bsz {
            total += self.actives[s].len();
            for &id in &self.actives[s] {
                if self.stamp[id as usize] != self.epoch {
                    self.stamp[id as usize] = self.epoch;
                    self.slot[id as usize] = self.union.len() as u32;
                    self.union.push(id);
                }
            }
        }
        // Inverted index: count members per union row, prefix-sum into
        // row starts, then fill in (sample, position) order.
        let u = self.union.len();
        self.row_starts.clear();
        self.row_starts.resize(u + 1, 0);
        for s in 0..bsz {
            for &id in &self.actives[s] {
                self.row_starts[self.slot[id as usize] as usize + 1] += 1;
            }
        }
        for k in 0..u {
            self.row_starts[k + 1] += self.row_starts[k];
        }
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.row_starts[..u]);
        self.members.clear();
        self.members.resize(total, 0);
        for s in 0..bsz {
            for (p, &id) in self.actives[s].iter().enumerate() {
                let slot = self.slot[id as usize] as usize;
                let c = self.cursor[slot] as usize;
                self.members[c] = ((s as u64) << 32) | p as u64;
                self.cursor[slot] = (c + 1) as u32;
            }
        }
        // First-touch stability: union slot `u` must hold the id found at
        // its own first (sample, position) member — the ordering contract
        // both the union-major gather (which writes through these
        // positions) and the trainer's gradient-sink row registration
        // depend on.
        #[cfg(debug_assertions)]
        for (u_slot, &id) in self.union.iter().enumerate() {
            let m = self.members[self.row_starts[u_slot] as usize];
            let (s, p) = ((m >> 32) as usize, (m & 0xFFFF_FFFF) as usize);
            debug_assert_eq!(
                self.actives[s][p], id,
                "union slot {u_slot} is not first-touch stable"
            );
        }
    }
}

/// Union-major fused sparse forward for one hidden layer: iterate the
/// batch union once, load each weight row a single time, and dot it
/// against every batch member whose active set contains it — writing
/// each result at the member's ranked-selection position, so per-sample
/// outputs are ordered exactly as [`Layer::forward_sparse`] orders them.
///
/// Bit-for-bit identical to the sample-major pass over the same active
/// sets: every output is the same `act(dot_row(w[id]) + b[id])` computed
/// by the same kernels; only the loop order — and therefore the
/// weight-plane traffic, `|union|` row loads instead of `Σ|active|` —
/// changes. Returns total forward multiplications across the batch
/// (identical accounting to the sample-major pass).
pub fn forward_union_major(
    layer: &Layer,
    inputs: &[LayerInput<'_>],
    lp: &LayerPlan,
    outs: &mut [SparseVec],
) -> u64 {
    let bsz = inputs.len();
    debug_assert!(lp.actives.len() >= bsz && outs.len() >= bsz);
    // Pre-shape every output: idx = the sample's ranked active set; val
    // is filled positionally by the gather below.
    let mut mults = 0u64;
    for s in 0..bsz {
        let out = &mut outs[s];
        out.idx.clear();
        out.idx.extend_from_slice(&lp.actives[s]);
        out.val.clear();
        out.val.resize(lp.actives[s].len(), 0.0);
        mults += (lp.actives[s].len() * inputs[s].active_len()) as u64;
    }
    for (u, &id) in lp.union.iter().enumerate() {
        // Software prefetch of the next union row: active ids are spread
        // over a wide weight plane, so the hardware prefetcher cannot
        // predict the row sequence. A prefetch is purely a cache hint —
        // it cannot change any computed value, so the bit-for-bit
        // contract holds by construction.
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if u + 1 < lp.union.len() {
            unsafe {
                std::arch::x86_64::_mm_prefetch::<{ std::arch::x86_64::_MM_HINT_T0 }>(
                    layer.w.row(lp.union[u + 1] as usize).as_ptr() as *const i8,
                );
            }
        }
        let row = layer.w.row(id as usize);
        let bias = layer.b[id as usize];
        let lo = lp.row_starts[u] as usize;
        let hi = lp.row_starts[u + 1] as usize;
        for &m in &lp.members[lo..hi] {
            let (s, p) = ((m >> 32) as usize, (m & 0xFFFF_FFFF) as usize);
            let z = inputs[s].dot_row(row) + bias;
            outs[s].val[p] = layer.act.apply(z);
        }
    }
    mults
}

/// Per-layer union active sets + per-sample membership for one batch —
/// the product of one-pass selection, consumed by the fused forward and
/// by batch-amortized maintenance/telemetry.
#[derive(Default)]
pub struct SparseBatchPlan {
    pub layers: Vec<LayerPlan>,
}

impl SparseBatchPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow to `n_hidden` layer plans with at least `bsz` per-sample
    /// slots each.
    pub fn ensure(&mut self, n_hidden: usize, bsz: usize) {
        if self.layers.len() < n_hidden {
            self.layers.resize_with(n_hidden, LayerPlan::default);
        }
        for lp in &mut self.layers[..n_hidden] {
            if lp.actives.len() < bsz {
                lp.actives.resize_with(bsz, Vec::new);
            }
        }
    }
}

/// Telemetry from one [`BatchExecutor::forward_batch`] run.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchRunStats {
    /// Fingerprint hash invocations this batch (= hidden layers; the
    /// per-request count would have been `hidden layers × batch`).
    pub hash_invocations: u64,
    /// Total selection multiplications across the batch.
    pub selection_mults: u64,
    /// Σ over layers of |union of the batch's active sets|.
    pub union_active: u64,
    /// Σ over layers and samples of |active set| — `total_active /
    /// union_active` is the batch's sharing factor (how much co-batched
    /// requests overlap in the neurons they fire).
    pub total_active: u64,
    /// Total forward multiplications across the batch (hidden layers +
    /// dense output layer). Identical between union-major and
    /// sample-major execution — the loop order changes, the arithmetic
    /// does not.
    pub forward_mults: u64,
    /// Modeled weight-plane traffic: each weight row load costs its full
    /// width (`n_in × 4` bytes), counted once per load. Sample-major
    /// loads `Σ|active|` rows per hidden layer; union-major loads
    /// `|union|` — so `weight_bytes / forward_mults` drops by the
    /// sharing factor on the hidden layers when the gather is on.
    pub weight_bytes: u64,
}

impl BatchRunStats {
    /// Modeled weight bytes per forward multiplication (lower = more
    /// row reuse).
    pub fn bytes_per_mult(&self) -> f64 {
        if self.forward_mults == 0 {
            0.0
        } else {
            self.weight_bytes as f64 / self.forward_mults as f64
        }
    }
}

/// The batched sparse forward driver: builds a [`SparseBatchPlan`] layer
/// by layer (selection must interleave with forwards — layer `l+1`'s
/// queries are layer `l`'s activations) and runs the fused sparse
/// forward over it, finishing with the always-dense output layer. Owns
/// every per-batch buffer; steady-state execution allocates only the
/// `B`-pointer `LayerInput` view vectors whose borrows change per batch.
///
/// Per-sample outputs: `acts[l][s]` (hidden sparse activations),
/// `logits[s]`, `sample_mults[s]` — the exact per-request multiplication
/// attribution per-request execution reported, so fusing a micro-batch
/// changes *when* hashing happens, never what a response says it cost.
#[derive(Default)]
pub struct BatchExecutor {
    pub plan: SparseBatchPlan,
    scratch: BatchSelectScratch,
    per_sample_sel: Vec<u64>,
    /// `acts[l][s]`: sparse activations of hidden layer `l`, sample `s`.
    pub acts: Vec<Vec<SparseVec>>,
    /// Per-sample output logits.
    pub logits: Vec<Vec<f32>>,
    /// Per-sample multiplication counters (selection + forward).
    pub sample_mults: Vec<MultCounters>,
    /// Stats of the most recent `forward_batch` run.
    pub last: BatchRunStats,
    /// Execution order for the hidden sparse forwards. `false` (default)
    /// = union-major gather (each weight row loaded once per batch);
    /// `true` = legacy sample-major loop (each sample re-walks its own
    /// rows). Outputs are bit-identical either way — the toggle exists
    /// for the equivalence tests and the kernel bench.
    pub sample_major: bool,
}

impl BatchExecutor {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure_capacity(&mut self, n_hidden: usize, bsz: usize) {
        self.plan.ensure(n_hidden, bsz);
        if self.acts.len() != n_hidden {
            self.acts.resize_with(n_hidden, Vec::new);
        }
        for per_layer in &mut self.acts {
            if per_layer.len() < bsz {
                per_layer.resize_with(bsz, SparseVec::new);
            }
        }
        if self.logits.len() < bsz {
            self.logits.resize_with(bsz, Vec::new);
        }
        if self.sample_mults.len() < bsz {
            self.sample_mults.resize(bsz, MultCounters::default());
        }
        if self.per_sample_sel.len() < bsz {
            self.per_sample_sel.resize(bsz, 0);
        }
    }

    /// Run the fused batched sparse forward: one [`TableView`] per hidden
    /// layer in `views`, `layers` = every network layer (hidden layers
    /// followed by the dense output layer). `rng` feeds only live
    /// (training-backend) views; frozen views ignore it.
    pub fn forward_batch<V: TableView>(
        &mut self,
        layers: &[Layer],
        views: &mut [V],
        sparsity: f32,
        rerank_factor: usize,
        xs: &[&[f32]],
        rng: &mut Pcg64,
    ) {
        let bsz = xs.len();
        let n_hidden = views.len();
        debug_assert_eq!(layers.len(), n_hidden + 1, "hidden layers + dense output layer");
        self.ensure_capacity(n_hidden, bsz);
        self.last = BatchRunStats::default();
        for m in &mut self.sample_mults[..bsz] {
            *m = MultCounters::default();
        }
        for l in 0..n_hidden {
            let layer = &layers[l];
            let b = budget(layer.n_out(), sparsity);
            let (prev, rest) = self.acts.split_at_mut(l);
            let inputs: Vec<LayerInput> = (0..bsz)
                .map(|s| {
                    if l == 0 {
                        LayerInput::Dense(xs[s])
                    } else {
                        LayerInput::Sparse(&prev[l - 1][s])
                    }
                })
                .collect();
            let lp = &mut self.plan.layers[l];
            let stats = select_batch_into(
                &mut views[l],
                layer,
                &inputs,
                b,
                rerank_factor,
                rng,
                &mut self.scratch,
                &mut self.per_sample_sel[..bsz],
                &mut lp.actives[..bsz],
            );
            lp.refresh_union(layer.n_out(), bsz);
            self.last.hash_invocations += stats.hash_invocations;
            self.last.selection_mults += stats.selection_mults;
            self.last.union_active += lp.union.len() as u64;
            let outs = &mut rest[0];
            let span = obs::begin(Stage::Gather);
            let fwd = if self.sample_major {
                let mut total = 0u64;
                for s in 0..bsz {
                    total += layer.forward_sparse(inputs[s], &lp.actives[s], &mut outs[s]);
                }
                total
            } else {
                forward_union_major(layer, &inputs, lp, &mut outs[..bsz])
            };
            obs::end(span);
            self.last.forward_mults += fwd;
            let rows_loaded = if self.sample_major {
                lp.actives[..bsz].iter().map(|a| a.len() as u64).sum::<u64>()
            } else {
                lp.union.len() as u64
            };
            self.last.weight_bytes += rows_loaded * layer.n_in() as u64 * 4;
            for s in 0..bsz {
                self.last.total_active += lp.actives[s].len() as u64;
                self.sample_mults[s].selection += self.per_sample_sel[s];
                // Per-request forward attribution: same formula
                // `forward_sparse` returns, independent of loop order.
                self.sample_mults[s].forward +=
                    (lp.actives[s].len() * inputs[s].active_len()) as u64;
            }
        }
        // Output layer: dense over all classes from the last sparse
        // activation (the paper never hashes the output layer).
        let span = obs::begin(Stage::Output);
        let out_layer = layers.last().expect("empty network");
        for s in 0..bsz {
            let input = if n_hidden == 0 {
                LayerInput::Dense(xs[s])
            } else {
                LayerInput::Sparse(&self.acts[n_hidden - 1][s])
            };
            let m = out_layer.forward_all(input, &mut self.logits[s]);
            self.sample_mults[s].forward += m;
            self.last.forward_mults += m;
        }
        obs::end(span);
        self.last.weight_bytes +=
            (bsz * out_layer.n_out() * out_layer.n_in()) as u64 * 4;
        obs::note_batch();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::activation::Activation;
    use crate::util::rng::Pcg64;

    fn layer(n_in: usize, n_out: usize, seed: u64) -> Layer {
        let mut rng = Pcg64::seeded(seed);
        Layer::new(n_in, n_out, Activation::ReLU, &mut rng)
    }

    fn queries(n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|s| (0..dim).map(|j| ((s * dim + j) as f32 * 0.19).sin()).collect())
            .collect()
    }

    #[test]
    fn live_batched_selection_matches_per_sample_queries() {
        let l = layer(20, 150, 3);
        let cfg = LshConfig { rerank_factor: 3, ..LshConfig::default() };
        let mut rng_a = Pcg64::seeded(7);
        let mut rng_b = Pcg64::seeded(7);
        let mut live_a = LayerTables::build(&l.w, cfg, &mut rng_a);
        let mut live_b = LayerTables::build(&l.w, cfg, &mut rng_b);
        let xs = queries(6, 20);
        let inputs: Vec<LayerInput> = xs.iter().map(|x| LayerInput::Dense(x)).collect();
        let b = budget(150, 0.1);

        let mut scratch = BatchSelectScratch::default();
        let mut per_sample = vec![0u64; 6];
        let mut outs: Vec<Vec<u32>> = vec![Vec::new(); 6];
        let stats = select_batch_into(
            &mut live_a,
            &l,
            &inputs,
            b,
            cfg.rerank_factor,
            &mut rng_a,
            &mut scratch,
            &mut per_sample,
            &mut outs,
        );
        assert_eq!(stats.hash_invocations, 1, "one hashing pass per batch");

        // Reference: per-sample hash + select through the same trait.
        let mut total = 0u64;
        for (s, x) in xs.iter().enumerate() {
            let mut fps = Vec::new();
            live_b.hash_query_fps(x, &mut fps);
            let mut one = Vec::new();
            let mut scored = Vec::new();
            let extra = live_b.select_prehashed(
                &l,
                x,
                &fps,
                b,
                cfg.rerank_factor,
                &mut rng_b,
                &mut scored,
                &mut one,
            );
            let hash = (cfg.k * cfg.l * 21) as u64;
            assert_eq!(one, outs[s], "sample {s} active set");
            assert_eq!(per_sample[s], hash + extra, "sample {s} attribution");
            total += hash + extra;
        }
        assert_eq!(stats.selection_mults, total);
    }

    #[test]
    fn frozen_view_matches_frozen_query() {
        let l = layer(16, 120, 11);
        let cfg = LshConfig { k: 6, l: 5, ..Default::default() };
        let mut rng = Pcg64::seeded(12);
        let live = LayerTables::build(&l.w, cfg, &mut rng);
        let frozen = FrozenLayerTables::freeze(&live);
        let xs = queries(5, 16);
        let inputs: Vec<LayerInput> = xs.iter().map(|x| LayerInput::Dense(x)).collect();
        let b = budget(120, 0.1);

        let mut scratch_view = FrozenQueryScratch::new();
        let mut view = FrozenTableView { tables: &frozen, scratch: &mut scratch_view };
        let mut scratch = BatchSelectScratch::default();
        let mut per_sample = vec![0u64; 5];
        let mut outs: Vec<Vec<u32>> = vec![Vec::new(); 5];
        let mut rng_unused = Pcg64::seeded(0);
        select_batch_into(
            &mut view,
            &l,
            &inputs,
            b,
            0,
            &mut rng_unused,
            &mut scratch,
            &mut per_sample,
            &mut outs,
        );

        let mut scratch_q = FrozenQueryScratch::new();
        for (s, x) in xs.iter().enumerate() {
            let mut one = Vec::new();
            let hash = frozen.query(x, b, &mut scratch_q, &mut one);
            assert_eq!(one, outs[s], "sample {s} must match the one-shot frozen query");
            assert_eq!(per_sample[s], hash, "sample {s} hashing attribution");
        }
    }

    #[test]
    fn layer_plan_union_is_first_touch_order() {
        let mut lp = LayerPlan::default();
        lp.actives = vec![vec![5, 1, 9], vec![1, 7, 5], vec![2]];
        lp.refresh_union(10, 3);
        assert_eq!(lp.union(), &[5, 1, 9, 7, 2]);
        // Recomputing with fewer samples shrinks the union.
        lp.refresh_union(10, 1);
        assert_eq!(lp.union(), &[5, 1, 9]);
    }

    #[test]
    fn union_major_gather_matches_sample_major_bitwise() {
        // Dense and sparse inputs, overlapping active sets with ragged
        // sizes: the gather must reproduce forward_sparse bit-for-bit,
        // including output ordering and mult accounting.
        let l = layer(20, 150, 31);
        let mut rng = Pcg64::seeded(32);
        let xs = queries(5, 20);
        let sparse_in: Vec<SparseVec> = xs
            .iter()
            .map(|x| {
                let mut sv = SparseVec::new();
                for (j, &v) in x.iter().enumerate().step_by(2) {
                    sv.push(j as u32, v);
                }
                sv
            })
            .collect();
        for dense in [true, false] {
            let inputs: Vec<LayerInput> = if dense {
                xs.iter().map(|x| LayerInput::Dense(x)).collect()
            } else {
                sparse_in.iter().map(LayerInput::Sparse).collect()
            };
            let mut lp = LayerPlan::default();
            lp.actives = (0..5).map(|s| rng.sample_indices(150, 10 + 7 * s)).collect();
            lp.refresh_union(150, 5);

            let mut want = vec![SparseVec::new(); 5];
            let mut want_mults = 0u64;
            for s in 0..5 {
                want_mults += l.forward_sparse(inputs[s], &lp.actives[s], &mut want[s]);
            }
            let mut got = vec![SparseVec::new(); 5];
            let got_mults = forward_union_major(&l, &inputs, &lp, &mut got);
            assert_eq!(got_mults, want_mults, "dense={dense} mult accounting");
            for s in 0..5 {
                assert_eq!(got[s].idx, want[s].idx, "dense={dense} sample {s} order");
                let gb: Vec<u32> = got[s].val.iter().map(|v| v.to_bits()).collect();
                let wb: Vec<u32> = want[s].val.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "dense={dense} sample {s} values");
            }
        }
    }

    #[test]
    fn executor_sample_major_toggle_is_bitwise_identical() {
        let mut rng = Pcg64::seeded(41);
        let l0 = layer(12, 80, 42);
        let l1 = layer(80, 60, 43);
        let out = layer(60, 4, 44);
        let cfg = LshConfig::default();
        let t0 = FrozenLayerTables::freeze(&LayerTables::build(&l0.w, cfg, &mut rng));
        let t1 = FrozenLayerTables::freeze(&LayerTables::build(&l1.w, cfg, &mut rng));
        let layers = [l0, l1, out];
        let xs = queries(6, 12);
        let xrefs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();

        let mut run = |sample_major: bool| {
            let mut exec = BatchExecutor::new();
            exec.sample_major = sample_major;
            let mut scratches = [FrozenQueryScratch::new(), FrozenQueryScratch::new()];
            let mut it = scratches.iter_mut();
            let mut views = vec![
                FrozenTableView { tables: &t0, scratch: it.next().unwrap() },
                FrozenTableView { tables: &t1, scratch: it.next().unwrap() },
            ];
            let mut rng_unused = Pcg64::seeded(0);
            exec.forward_batch(&layers, &mut views, 0.2, 0, &xrefs, &mut rng_unused);
            exec
        };
        let fused = run(false);
        let legacy = run(true);
        for s in 0..6 {
            let fb: Vec<u32> = fused.logits[s].iter().map(|v| v.to_bits()).collect();
            let lb: Vec<u32> = legacy.logits[s].iter().map(|v| v.to_bits()).collect();
            assert_eq!(fb, lb, "sample {s} logits");
            assert_eq!(fused.sample_mults[s], legacy.sample_mults[s], "sample {s} mults");
        }
        assert_eq!(fused.last.forward_mults, legacy.last.forward_mults);
        // Union-major never loads more weight rows than sample-major.
        assert!(fused.last.weight_bytes <= legacy.last.weight_bytes);
        assert!(fused.last.bytes_per_mult() <= legacy.last.bytes_per_mult());
    }

    #[test]
    fn executor_matches_per_sample_frozen_inference() {
        // Two hidden layers + dense output; the fused executor must equal
        // a hand-rolled per-sample pass over the same frozen stacks.
        let mut rng = Pcg64::seeded(21);
        let l0 = layer(12, 80, 22);
        let l1 = layer(80, 60, 23);
        let out = layer(60, 4, 24);
        let cfg = LshConfig::default();
        let t0 = FrozenLayerTables::freeze(&LayerTables::build(&l0.w, cfg, &mut rng));
        let t1 = FrozenLayerTables::freeze(&LayerTables::build(&l1.w, cfg, &mut rng));
        let layers = [l0, l1, out];
        let sparsity = 0.2;
        let xs = queries(4, 12);
        let xrefs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();

        let mut exec = BatchExecutor::new();
        let mut scratches = [FrozenQueryScratch::new(), FrozenQueryScratch::new()];
        {
            let mut it = scratches.iter_mut();
            let mut views = vec![
                FrozenTableView { tables: &t0, scratch: it.next().unwrap() },
                FrozenTableView { tables: &t1, scratch: it.next().unwrap() },
            ];
            let mut rng_unused = Pcg64::seeded(0);
            exec.forward_batch(&layers, &mut views, sparsity, 0, &xrefs, &mut rng_unused);
        }
        assert_eq!(exec.last.hash_invocations, 2, "one invocation per hidden layer");
        assert!(exec.last.total_active >= exec.last.union_active);

        let mut scratch = FrozenQueryScratch::new();
        for (s, x) in xs.iter().enumerate() {
            let mut active = Vec::new();
            let mut a0 = SparseVec::new();
            let mut a1 = SparseVec::new();
            let mut logits = Vec::new();
            let mut mults = MultCounters::default();
            mults.selection +=
                t0.query(x, budget(80, sparsity), &mut scratch, &mut active);
            mults.forward += layers[0].forward_sparse(LayerInput::Dense(x), &active, &mut a0);
            let mut q = vec![0.0f32; 80];
            densify_into(LayerInput::Sparse(&a0), &mut q);
            mults.selection +=
                t1.query(&q, budget(60, sparsity), &mut scratch, &mut active);
            mults.forward +=
                layers[1].forward_sparse(LayerInput::Sparse(&a0), &active, &mut a1);
            mults.forward += layers[2].forward_all(LayerInput::Sparse(&a1), &mut logits);

            assert_eq!(exec.logits[s], logits, "sample {s} logits");
            assert_eq!(exec.acts[1][s].idx, a1.idx, "sample {s} layer-1 active set");
            assert_eq!(exec.sample_mults[s].total(), mults.total(), "sample {s} mults");
        }
    }

    #[test]
    fn sharded_live_backend_at_s1_matches_unsharded_bitwise() {
        // The tentpole parity contract at the exec layer: one shard must
        // reproduce the unsharded backend's active sets, attribution and
        // RNG stream exactly.
        let l = layer(18, 130, 51);
        let cfg = LshConfig { rerank_factor: 2, ..LshConfig::default() };
        let mut rng_a = Pcg64::seeded(52);
        let mut rng_b = Pcg64::seeded(52);
        let mut unsharded = LayerTables::build(&l.w, cfg, &mut rng_a);
        let mut sharded = ShardedLayerTables::build(&l.w, cfg, 1, &mut rng_b);
        assert_eq!(TableView::fps_width(&sharded), cfg.l);
        let xs = queries(5, 18);
        let inputs: Vec<LayerInput> = xs.iter().map(|x| LayerInput::Dense(x)).collect();
        let b = budget(130, 0.1);
        let mut scratch = BatchSelectScratch::default();
        let mut per_a = vec![0u64; 5];
        let mut outs_a: Vec<Vec<u32>> = vec![Vec::new(); 5];
        let stats_a = select_batch_into(
            &mut unsharded,
            &l,
            &inputs,
            b,
            cfg.rerank_factor,
            &mut rng_a,
            &mut scratch,
            &mut per_a,
            &mut outs_a,
        );
        let mut per_b = vec![0u64; 5];
        let mut outs_b: Vec<Vec<u32>> = vec![Vec::new(); 5];
        let stats_b = select_batch_into(
            &mut sharded,
            &l,
            &inputs,
            b,
            cfg.rerank_factor,
            &mut rng_b,
            &mut scratch,
            &mut per_b,
            &mut outs_b,
        );
        assert_eq!(outs_a, outs_b, "S=1 active sets must be bit-identical");
        assert_eq!(per_a, per_b, "S=1 per-sample attribution");
        assert_eq!(stats_a.selection_mults, stats_b.selection_mults);
        assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG streams must stay in lock-step");
    }

    #[test]
    fn sharded_frozen_view_at_s1_matches_single_frozen_view() {
        let l = layer(14, 110, 61);
        let cfg = LshConfig { k: 5, l: 4, ..Default::default() };
        let mut rng_a = Pcg64::seeded(62);
        let mut rng_b = Pcg64::seeded(62);
        let single = FrozenLayerTables::freeze(&LayerTables::build(&l.w, cfg, &mut rng_a));
        let sharded = crate::lsh::sharded::ShardedFrozenTables::freeze(
            &ShardedLayerTables::build(&l.w, cfg, 1, &mut rng_b),
        );
        let xs = queries(4, 14);
        let inputs: Vec<LayerInput> = xs.iter().map(|x| LayerInput::Dense(x)).collect();
        let b = budget(110, 0.1);
        let mut scratch = BatchSelectScratch::default();
        let mut rng_unused = Pcg64::seeded(0);
        let mut s_scratch = FrozenQueryScratch::new();
        let mut view_a = FrozenTableView { tables: &single, scratch: &mut s_scratch };
        let mut per_a = vec![0u64; 4];
        let mut outs_a: Vec<Vec<u32>> = vec![Vec::new(); 4];
        select_batch_into(
            &mut view_a,
            &l,
            &inputs,
            b,
            0,
            &mut rng_unused,
            &mut scratch,
            &mut per_a,
            &mut outs_a,
        );
        let mut scratches = vec![FrozenQueryScratch::new()];
        let mut view_b = ShardedFrozenView::new(&sharded, &mut scratches);
        let mut per_b = vec![0u64; 4];
        let mut outs_b: Vec<Vec<u32>> = vec![Vec::new(); 4];
        select_batch_into(
            &mut view_b,
            &l,
            &inputs,
            b,
            0,
            &mut rng_unused,
            &mut scratch,
            &mut per_b,
            &mut outs_b,
        );
        assert_eq!(outs_a, outs_b, "frozen S=1 active sets");
        assert_eq!(per_a, per_b, "frozen S=1 attribution");
    }

    #[test]
    fn sharded_batch_selection_matches_batch_of_one() {
        // The general batching contract holds for S > 1 too: co-batching
        // samples changes when hashing happens, never what is selected.
        let l = layer(16, 120, 71);
        let cfg = LshConfig { k: 4, l: 3, ..Default::default() };
        let mut rng_a = Pcg64::seeded(72);
        let mut rng_b = Pcg64::seeded(72);
        let mut batch_view = ShardedLayerTables::build(&l.w, cfg, 4, &mut rng_a);
        let mut one_view = ShardedLayerTables::build(&l.w, cfg, 4, &mut rng_b);
        let xs = queries(6, 16);
        let inputs: Vec<LayerInput> = xs.iter().map(|x| LayerInput::Dense(x)).collect();
        let b = budget(120, 0.15);
        let mut scratch = BatchSelectScratch::default();
        let mut per_sample = vec![0u64; 6];
        let mut outs: Vec<Vec<u32>> = vec![Vec::new(); 6];
        select_batch_into(
            &mut batch_view,
            &l,
            &inputs,
            b,
            0,
            &mut rng_a,
            &mut scratch,
            &mut per_sample,
            &mut outs,
        );
        for (s, input) in inputs.iter().enumerate() {
            let mut one_scratch = BatchSelectScratch::default();
            let mut one_mults = [0u64];
            let mut one_out = vec![Vec::new()];
            select_batch_into(
                &mut one_view,
                &l,
                &[*input],
                b,
                0,
                &mut rng_b,
                &mut one_scratch,
                &mut one_mults,
                &mut one_out,
            );
            assert_eq!(one_out[0], outs[s], "sample {s} active set");
            assert_eq!(one_mults[0], per_sample[s], "sample {s} attribution");
        }
    }
}
