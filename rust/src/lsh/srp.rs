//! Signed random projection (SimHash) family.
//!
//! Each of the K·L bits is `sign(r · x)` for a fixed gaussian direction `r`
//! (Charikar 2002): `Pr[h(x)=h(y)] = 1 - θ(x,y)/π`, monotone in cosine
//! similarity. The paper's §5.3 uses exactly this — "the sign of an
//! asymmetrically transformed random projection" — with the asymmetric
//! transform supplied by [`crate::lsh::alsh`].

use crate::lsh::family::LshFamily;
use crate::tensor::matrix::Matrix;
use crate::tensor::vecops::dot;
use crate::util::rng::Pcg64;

/// Plain symmetric SRP over `dim`-dimensional vectors: K·L gaussian
/// directions stored row-wise (row = one projection).
#[derive(Clone, Debug)]
pub struct SrpHash {
    k: usize,
    l: usize,
    dim: usize,
    /// (K·L) x dim projection directions; table j uses rows [j*K, (j+1)*K).
    projections: Matrix,
}

impl SrpHash {
    pub fn new(dim: usize, k: usize, l: usize, rng: &mut Pcg64) -> Self {
        assert!(k >= 1 && k <= 32, "K must be in 1..=32");
        assert!(l >= 1, "L must be >= 1");
        SrpHash { k, l, dim, projections: Matrix::randn(k * l, dim, rng) }
    }

    /// Fingerprint for table `j` (symmetric — same map for data and query).
    #[inline]
    pub fn fingerprint(&self, x: &[f32], j: usize) -> u32 {
        debug_assert_eq!(x.len(), self.dim);
        let mut fp = 0u32;
        for i in 0..self.k {
            let row = self.projections.row(j * self.k + i);
            fp = (fp << 1) | (dot(row, x) >= 0.0) as u32;
        }
        fp
    }

    /// One-pass batched fingerprinting: hash `bsz` vectors (rows of
    /// `x_plane`, each `dim` wide) into `out` (`bsz × L`, row-major —
    /// `out[s*L + j]` is sample `s`'s table-`j` fingerprint). The loop is
    /// projection-row-outer / sample-inner, so each of the K·L gaussian
    /// directions is loaded from memory once per *batch* instead of once
    /// per vector — the cache-amortization that makes the shared batched
    /// execution core's single hashing pass pay. Bit-for-bit identical to
    /// calling [`SrpHash::fingerprint`] per sample (same dots, same bit
    /// assembly order).
    pub fn hash_batch(&self, x_plane: &[f32], bsz: usize, out: &mut [u32]) {
        debug_assert_eq!(x_plane.len(), bsz * self.dim);
        debug_assert_eq!(out.len(), bsz * self.l);
        out.iter_mut().for_each(|o| *o = 0);
        for j in 0..self.l {
            for i in 0..self.k {
                let row = self.projections.row(j * self.k + i);
                for s in 0..bsz {
                    let x = &x_plane[s * self.dim..(s + 1) * self.dim];
                    let bit = (dot(row, x) >= 0.0) as u32;
                    let fp = &mut out[s * self.l + j];
                    *fp = (*fp << 1) | bit;
                }
            }
        }
    }

    /// Access the raw projection directions (used by the AOT simhash
    /// artifact so python and rust hash identically).
    pub fn projections(&self) -> &Matrix {
        &self.projections
    }

    /// Build from externally supplied projections (for cross-language
    /// equivalence tests against the pallas kernel).
    pub fn from_projections(dim: usize, k: usize, l: usize, projections: Matrix) -> Self {
        assert_eq!(projections.rows(), k * l);
        assert_eq!(projections.cols(), dim);
        SrpHash { k, l, dim, projections }
    }
}

impl LshFamily for SrpHash {
    fn k(&self) -> usize {
        self.k
    }
    fn l(&self) -> usize {
        self.l
    }
    fn dim(&self) -> usize {
        self.dim
    }

    fn hash_data(&self, x: &[f32], out: &mut [u32]) {
        debug_assert_eq!(out.len(), self.l);
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.fingerprint(x, j);
        }
    }

    fn hash_query(&self, q: &[f32], out: &mut [u32]) {
        self.hash_data(q, out); // symmetric
    }
}

/// Reference bit computation used in tests.
pub fn srp_bits_reference(projections: &Matrix, x: &[f32], j: usize, k: usize) -> Vec<bool> {
    (0..k).map(|i| dot(projections.row(j * k + i), x) >= 0.0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bitpack::pack_bits;

    fn family() -> SrpHash {
        let mut rng = Pcg64::seeded(42);
        SrpHash::new(16, 6, 5, &mut rng)
    }

    #[test]
    fn fingerprint_matches_bit_reference() {
        let f = family();
        let mut rng = Pcg64::seeded(1);
        for _ in 0..20 {
            let x: Vec<f32> = (0..16).map(|_| rng.gaussian()).collect();
            for j in 0..f.l() {
                let expect = pack_bits(&srp_bits_reference(f.projections(), &x, j, f.k()));
                assert_eq!(f.fingerprint(&x, j), expect);
            }
        }
    }

    #[test]
    fn identical_vectors_always_collide() {
        let f = family();
        let mut rng = Pcg64::seeded(2);
        let x: Vec<f32> = (0..16).map(|_| rng.gaussian()).collect();
        assert_eq!(f.data_fingerprints(&x), f.query_fingerprints(&x));
    }

    #[test]
    fn scaling_does_not_change_fingerprint() {
        // sign(r·cx) == sign(r·x) for c > 0.
        let f = family();
        let mut rng = Pcg64::seeded(3);
        let x: Vec<f32> = (0..16).map(|_| rng.gaussian()).collect();
        let x2: Vec<f32> = x.iter().map(|v| v * 7.5).collect();
        assert_eq!(f.data_fingerprints(&x), f.data_fingerprints(&x2));
    }

    #[test]
    fn collision_probability_is_monotone_in_angle() {
        // Empirically: closer vectors share more fingerprint bits.
        let mut rng = Pcg64::seeded(4);
        let dim = 32;
        let trials = 400;
        let mut close_coll = 0usize;
        let mut far_coll = 0usize;
        for t in 0..trials {
            let f = SrpHash::new(dim, 1, 8, &mut Pcg64::seeded(1000 + t as u64));
            let x: Vec<f32> = (0..dim).map(|_| rng.gaussian()).collect();
            // close: small perturbation; far: independent vector
            let close: Vec<f32> = x.iter().map(|v| v + 0.1 * rng.gaussian()).collect();
            let far: Vec<f32> = (0..dim).map(|_| rng.gaussian()).collect();
            let fx = f.data_fingerprints(&x);
            let fc = f.data_fingerprints(&close);
            let ff = f.data_fingerprints(&far);
            close_coll += fx.iter().zip(&fc).filter(|(a, b)| a == b).count();
            far_coll += fx.iter().zip(&ff).filter(|(a, b)| a == b).count();
        }
        assert!(
            close_coll > far_coll + trials,
            "close {close_coll} should collide far more than far {far_coll}"
        );
    }

    #[test]
    fn fingerprints_fit_in_k_bits() {
        let f = family();
        let mut rng = Pcg64::seeded(5);
        for _ in 0..50 {
            let x: Vec<f32> = (0..16).map(|_| rng.gaussian()).collect();
            for fp in f.data_fingerprints(&x) {
                assert!(fp < (1 << f.k()));
            }
        }
    }

    #[test]
    #[should_panic(expected = "K must be")]
    fn k_over_32_rejected() {
        SrpHash::new(4, 33, 1, &mut Pcg64::seeded(0));
    }

    #[test]
    fn hash_batch_matches_per_sample_fingerprints() {
        let f = family();
        let mut rng = Pcg64::seeded(6);
        let bsz = 7;
        let plane: Vec<f32> = (0..bsz * 16).map(|_| rng.gaussian()).collect();
        let mut out = vec![0u32; bsz * f.l()];
        f.hash_batch(&plane, bsz, &mut out);
        for s in 0..bsz {
            let x = &plane[s * 16..(s + 1) * 16];
            for j in 0..f.l() {
                assert_eq!(out[s * f.l() + j], f.fingerprint(x, j), "sample {s} table {j}");
            }
        }
    }
}
