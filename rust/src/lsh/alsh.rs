//! Asymmetric LSH for Maximum Inner Product Search.
//!
//! Implements the Simple-ALSH construction (Neyshabur & Srebro 2015;
//! Shrivastava & Li UAI 2015 "improved ALSH for MIPS"): scale every data
//! vector by a global constant `M` so that `||x||/M ≤ 1`, then embed
//!
//!   data :  P(x) = [x/M ; sqrt(1 − ||x/M||²)]
//!   query:  Q(q) = [q/||q|| ; 0]
//!
//! after which `cos(P(x), Q(q)) = (x·q)/(M·||q||)` — monotone in the inner
//! product `x·q` for a fixed query. SRP on the embedded vectors therefore
//! gives collision probability monotone in the activation, which is what
//! Theorem 1 of the paper requires.
//!
//! Because neuron weights drift during training, `M` is chosen with
//! headroom at build time; [`AlshMips::fits`] reports whether a vector
//! still fits, and the layer tables trigger a rebuild when it does not.

use crate::lsh::family::LshFamily;
use crate::lsh::srp::SrpHash;
use crate::tensor::vecops::{norm, norm_sq};
use crate::util::rng::Pcg64;

/// Headroom multiplier applied to the max data norm at build time, so small
/// weight updates do not force an immediate rebuild.
pub const NORM_HEADROOM: f32 = 1.25;

#[derive(Clone, Debug)]
pub struct AlshMips {
    srp: SrpHash,
    dim: usize,
    /// Global scaling constant M (max data norm × headroom).
    max_norm: f32,
}

impl AlshMips {
    /// Build for `dim`-dimensional weight vectors whose current max norm is
    /// `max_data_norm`.
    pub fn new(dim: usize, k: usize, l: usize, max_data_norm: f32, rng: &mut Pcg64) -> Self {
        let max_norm = (max_data_norm * NORM_HEADROOM).max(f32::MIN_POSITIVE);
        AlshMips { srp: SrpHash::new(dim + 1, k, l, rng), dim, max_norm }
    }

    pub fn max_norm(&self) -> f32 {
        self.max_norm
    }

    /// Borrow the underlying SRP family (snapshot serialization reads the
    /// raw projection directions from here).
    pub fn srp(&self) -> &SrpHash {
        &self.srp
    }

    /// Reassemble from serialized parts. `max_norm` is the *stored* scaling
    /// constant M (headroom already applied at original build time — do not
    /// reapply it), and `srp` must hash the (dim+1)-dimensional embedding.
    pub fn from_parts(dim: usize, max_norm: f32, srp: SrpHash) -> Result<Self, String> {
        if srp.dim() != dim + 1 {
            return Err(format!(
                "ALSH projections hash dim {} but expected embedded dim {}",
                srp.dim(),
                dim + 1
            ));
        }
        if !(max_norm > 0.0 && max_norm.is_finite()) {
            return Err(format!("invalid ALSH scaling constant M = {max_norm}"));
        }
        Ok(AlshMips { srp, dim, max_norm })
    }

    /// Does a data vector with this norm still fit under M?
    #[inline]
    pub fn fits(&self, data_norm: f32) -> bool {
        data_norm <= self.max_norm
    }

    /// Embed a data vector: [x/M ; sqrt(1 − ||x/M||²)].
    pub fn embed_data(&self, x: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(x.len(), self.dim);
        out.clear();
        let inv_m = 1.0 / self.max_norm;
        let mut nsq = 0.0f32;
        for &v in x {
            let s = v * inv_m;
            nsq += s * s;
            out.push(s);
        }
        // Clamp for safety: nsq can exceed 1 only if `fits` was violated.
        out.push((1.0 - nsq.min(1.0)).sqrt());
    }

    /// Embed a query vector: [q/||q|| ; 0].
    pub fn embed_query(&self, q: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(q.len(), self.dim);
        out.clear();
        let n = norm(q);
        let inv = if n > 0.0 { 1.0 / n } else { 0.0 };
        out.extend(q.iter().map(|v| v * inv));
        out.push(0.0);
    }

    /// One-pass batched query hashing for the shared batched execution
    /// core: embed every query of the batch (rows of `q_plane`, each
    /// `dim` wide) into `embed_plane` (reused scratch, `bsz × (dim+1)`),
    /// then sweep the K·L projection rows once over all samples
    /// ([`SrpHash::hash_batch`]). `out` receives `bsz × L` fingerprints,
    /// row-major, bit-for-bit identical to per-sample
    /// [`LshFamily::hash_query`].
    pub fn hash_queries_batch(
        &self,
        q_plane: &[f32],
        bsz: usize,
        embed_plane: &mut Vec<f32>,
        out: &mut [u32],
    ) {
        debug_assert_eq!(q_plane.len(), bsz * self.dim);
        let d = self.dim;
        let ed = d + 1;
        embed_plane.clear();
        embed_plane.resize(bsz * ed, 0.0);
        for s in 0..bsz {
            let q = &q_plane[s * d..(s + 1) * d];
            let e = &mut embed_plane[s * ed..(s + 1) * ed];
            let n = norm(q);
            let inv = if n > 0.0 { 1.0 / n } else { 0.0 };
            for (ev, qv) in e[..d].iter_mut().zip(q) {
                *ev = qv * inv;
            }
            e[d] = 0.0;
        }
        self.srp.hash_batch(embed_plane, bsz, out);
    }
}

impl LshFamily for AlshMips {
    fn k(&self) -> usize {
        self.srp.k()
    }
    fn l(&self) -> usize {
        self.srp.l()
    }
    fn dim(&self) -> usize {
        self.dim
    }

    fn hash_data(&self, x: &[f32], out: &mut [u32]) {
        let mut e = Vec::with_capacity(self.dim + 1);
        self.embed_data(x, &mut e);
        self.srp.hash_data(&e, out);
    }

    fn hash_query(&self, q: &[f32], out: &mut [u32]) {
        let mut e = Vec::with_capacity(self.dim + 1);
        self.embed_query(q, &mut e);
        self.srp.hash_data(&e, out);
    }
}

/// Compute the max L2 norm over a set of row vectors (build-time helper).
pub fn max_row_norm(rows: impl Iterator<Item = impl AsRef<[f32]>>) -> f32 {
    rows.map(|r| norm_sq(r.as_ref())).fold(0.0f32, f32::max).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_embedding_is_unit_norm() {
        let mut rng = Pcg64::seeded(1);
        let f = AlshMips::new(8, 6, 3, 2.0, &mut rng);
        let mut out = Vec::new();
        for _ in 0..20 {
            let x: Vec<f32> = (0..8).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            f.embed_data(&x, &mut out);
            assert_eq!(out.len(), 9);
            assert!((norm(&out) - 1.0).abs() < 1e-4, "embedding must be unit norm");
        }
    }

    #[test]
    fn query_embedding_is_unit_norm_with_zero_tail() {
        let mut rng = Pcg64::seeded(2);
        let f = AlshMips::new(8, 6, 3, 2.0, &mut rng);
        let q: Vec<f32> = (0..8).map(|_| rng.gaussian()).collect();
        let mut out = Vec::new();
        f.embed_query(&q, &mut out);
        assert_eq!(out[8], 0.0);
        assert!((norm(&out) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn zero_query_does_not_nan() {
        let mut rng = Pcg64::seeded(3);
        let f = AlshMips::new(4, 4, 2, 1.0, &mut rng);
        let mut out = Vec::new();
        f.embed_query(&[0.0; 4], &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
        let fps = f.query_fingerprints(&[0.0; 4]);
        assert_eq!(fps.len(), 2);
    }

    #[test]
    fn fits_respects_headroom() {
        let mut rng = Pcg64::seeded(4);
        let f = AlshMips::new(4, 4, 2, 1.0, &mut rng);
        assert!(f.fits(1.0));
        assert!(f.fits(1.2));
        assert!(!f.fits(1.3));
    }

    #[test]
    fn collision_rate_monotone_in_inner_product() {
        // Build many 1-bit families; nodes with larger q·w must collide with
        // the query more often — the empirical core of Theorem 1.
        let dim = 24;
        let mut rng = Pcg64::seeded(5);
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian()).collect();
        // Three data vectors with increasing inner product with q.
        let qn = norm(&q);
        let unit_q: Vec<f32> = q.iter().map(|v| v / qn).collect();
        let mk = |align: f32, rng: &mut Pcg64| -> Vec<f32> {
            // align * q_hat + (1-align) * noise, rescaled to norm 0.8
            let noise: Vec<f32> = (0..dim).map(|_| rng.gaussian()).collect();
            let nn = norm(&noise);
            let mut v: Vec<f32> = unit_q
                .iter()
                .zip(&noise)
                .map(|(uq, nz)| align * uq + (1.0 - align) * nz / nn)
                .collect();
            let vn = norm(&v);
            for x in &mut v {
                *x *= 0.8 / vn;
            }
            v
        };
        let lo = mk(0.1, &mut rng);
        let mid = mk(0.5, &mut rng);
        let hi = mk(0.9, &mut rng);
        let ip = |a: &[f32]| crate::tensor::vecops::dot(a, &q);
        assert!(ip(&lo) < ip(&mid) && ip(&mid) < ip(&hi));

        let trials = 600;
        let mut coll = [0usize; 3];
        for t in 0..trials {
            let f = AlshMips::new(dim, 1, 1, 0.8, &mut Pcg64::seeded(9000 + t));
            let fq = f.query_fingerprints(&q)[0];
            for (i, v) in [&lo, &mid, &hi].iter().enumerate() {
                if f.data_fingerprints(v)[0] == fq {
                    coll[i] += 1;
                }
            }
        }
        assert!(
            coll[0] < coll[1] && coll[1] < coll[2],
            "collision counts should increase with inner product: {coll:?}"
        );
    }

    #[test]
    fn batched_query_hashing_matches_per_query() {
        let mut rng = Pcg64::seeded(11);
        let f = AlshMips::new(12, 5, 4, 1.5, &mut rng);
        let bsz = 6;
        let plane: Vec<f32> = (0..bsz * 12).map(|_| rng.gaussian()).collect();
        let mut embed = Vec::new();
        let mut out = vec![0u32; bsz * f.l()];
        f.hash_queries_batch(&plane, bsz, &mut embed, &mut out);
        for s in 0..bsz {
            let q = &plane[s * 12..(s + 1) * 12];
            assert_eq!(&out[s * f.l()..(s + 1) * f.l()], f.query_fingerprints(q).as_slice());
        }
    }

    #[test]
    fn max_row_norm_helper() {
        let rows: Vec<Vec<f32>> = vec![vec![3.0, 4.0], vec![1.0, 0.0]];
        assert!((max_row_norm(rows.iter()) - 5.0).abs() < 1e-6);
    }
}
