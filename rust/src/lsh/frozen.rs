//! Frozen (read-only) LSH table views for concurrent inference serving.
//!
//! Training-time [`LayerTables`] interleave probing with mutation and keep
//! their scratch buffers inline, so a query takes `&mut self` and the
//! caller's RNG — fine for one trainer thread, unusable for a serving pool
//! where N workers probe the same tables at once. A [`FrozenLayerTables`]
//! is the immutable split: buckets + hash family shared behind an `Arc`,
//! with every per-query buffer moved into a per-thread
//! [`FrozenQueryScratch`] (the same reuse discipline as the batched
//! selection path's `query_prehashed` probe buffers).
//!
//! **Determinism contract:** serving results must not depend on worker
//! count or request interleaving (pinned by `tests/serve.rs`). The two
//! places training-time queries consume caller RNG — crowded-bucket
//! reservoir sub-sampling and the empty-result fallback — instead draw
//! from a private RNG seeded from the query's own fingerprints, so any
//! worker computes bit-identical active sets for the same input while
//! distinct queries still sample crowded buckets differently.

use crate::lsh::alsh::AlshMips;
use crate::lsh::family::LshFamily;
use crate::lsh::layered::{probe_and_rank, LayerTables, LshConfig, ProbeScratch};
use crate::lsh::multiprobe::ProbeGen;
use crate::lsh::table::HashTable;
use crate::obs::health::{HealthTally, TableHealth};
use crate::util::rng::{splitmix64, Pcg64};
use std::sync::Arc;

/// Immutable per-layer (K, L) table stack. All fields are plain data, so
/// the struct is `Send + Sync` and can be shared across worker threads
/// behind an `Arc` without locks. `Clone` exists for the publication path
/// (`publish::ModelParts` re-publishes table stacks wholesale); queries
/// never clone.
#[derive(Clone)]
pub struct FrozenLayerTables {
    cfg: LshConfig,
    family: AlshMips,
    tables: Vec<HashTable>,
    n_nodes: usize,
    /// Table-health accounting, shared across clones (publication clones
    /// table stacks wholesale; the health story of an epoch's tables is
    /// one story, however many handles exist) and across serve workers.
    health: Arc<HealthTally>,
    /// The live stack's [`LayerTables::mutation_stamp`] at freeze time.
    /// [`FrozenLayerTables::refreeze_delta`] compares this against the
    /// live stamp to decide whether the previous epoch's frozen view is
    /// still exact. `u64::MAX` marks a snapshot-loaded stack, which has no
    /// live counterpart and therefore never matches.
    frozen_stamp: u64,
}

/// Per-thread query workspace: fingerprints, membership stamps, collision
/// counts, probe generators and the candidate union. One instance per
/// serving worker, reused across every query and every layer (buffers grow
/// to the widest layer and stay there).
#[derive(Default)]
pub struct FrozenQueryScratch {
    stamp: Vec<u32>,
    counts: Vec<u8>,
    query_epoch: u32,
    fps: Vec<u32>,
    candidates: Vec<u32>,
    probe_scratch: Vec<u32>,
    addrs: Vec<u32>,
    gens: Vec<ProbeGen>,
    /// Batched-hashing scratch (ALSH query embeddings, `B × (dim+1)`) —
    /// used by the shared batched execution core (`exec`), which hashes a
    /// whole micro-batch through this scratch in one pass.
    pub(crate) embed_plane: Vec<f32>,
    /// Batched-fingerprint staging for the sharded serving view (each
    /// shard hashes the batch with its own family into here before the
    /// fingerprints scatter into the interleaved per-sample layout).
    pub(crate) fps_batch: Vec<u32>,
    /// Per-shard local-id staging for the sharded serving view (merged
    /// into global ids with the shard's base offset).
    pub(crate) sub_out: Vec<u32>,
}

impl FrozenQueryScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fingerprints of the most recent query (one per table).
    pub fn fingerprints(&self) -> &[u32] {
        &self.fps
    }
}

impl FrozenLayerTables {
    /// Clone a live training table stack into a frozen view (scratch state
    /// is not carried over — it belongs to the query side now).
    pub fn freeze(live: &LayerTables) -> Self {
        FrozenLayerTables {
            cfg: live.config(),
            family: live.family().clone(),
            tables: live.tables().to_vec(),
            n_nodes: live.n_nodes(),
            health: Arc::new(HealthTally::new(live.n_nodes())),
            frozen_stamp: live.mutation_stamp(),
        }
    }

    /// Delta re-freeze: if `live` has not mutated since `prev` was frozen
    /// (mutation stamps match), the previous epoch's frozen view is still
    /// exact — share it (tables, family *and* health tally: unchanged
    /// tables are the same health story). Any mutation — including a full
    /// rebuild, which bumps the stamp — falls back to a fresh
    /// [`FrozenLayerTables::freeze`]. Either way the result is
    /// bucket-for-bucket what `freeze(live)` would produce; note the
    /// freeze itself is already O(touched) in deep bytes because
    /// [`HashTable`] buckets are copy-on-write.
    pub fn refreeze_delta(live: &LayerTables, prev: &FrozenLayerTables) -> Self {
        debug_assert_eq!(prev.n_nodes, live.n_nodes(), "refreeze across different layers");
        if prev.frozen_stamp == live.mutation_stamp() {
            prev.clone()
        } else {
            FrozenLayerTables::freeze(live)
        }
    }

    /// The live mutation stamp this view was frozen at (`u64::MAX` for
    /// snapshot-loaded stacks).
    pub fn frozen_stamp(&self) -> u64 {
        self.frozen_stamp
    }

    /// Reassemble from snapshot parts, validating table count against the
    /// config and every table against `n_nodes`.
    pub fn from_parts(
        cfg: LshConfig,
        family: AlshMips,
        tables: Vec<HashTable>,
        n_nodes: usize,
    ) -> Result<Self, String> {
        if tables.len() != cfg.l {
            return Err(format!("expected {} tables, got {}", cfg.l, tables.len()));
        }
        for (t, table) in tables.iter().enumerate() {
            if table.k() != cfg.k {
                return Err(format!("table {t} has K={}, config says {}", table.k(), cfg.k));
            }
            if table.node_fingerprints().len() != n_nodes {
                return Err(format!(
                    "table {t} capacity {} != {n_nodes} nodes",
                    table.node_fingerprints().len()
                ));
            }
        }
        let health = Arc::new(HealthTally::new(n_nodes));
        Ok(FrozenLayerTables { cfg, family, tables, n_nodes, health, frozen_stamp: u64::MAX })
    }

    pub fn config(&self) -> LshConfig {
        self.cfg
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    pub fn family(&self) -> &AlshMips {
        &self.family
    }

    pub fn tables(&self) -> &[HashTable] {
        &self.tables
    }

    /// The running health counters (shared across clones and workers).
    pub fn health_tally(&self) -> &HealthTally {
        &self.health
    }

    /// Computed health snapshot for this frozen epoch's tables.
    pub fn health_snapshot(&self) -> TableHealth {
        let sizes: Vec<Vec<usize>> = self.tables.iter().map(|t| t.bucket_sizes()).collect();
        // Frozen stacks never rebuild in place — a new epoch is a new stack.
        TableHealth::compute(&sizes, 0, &self.health)
    }

    /// Multiplications one query spends on hashing: K·L inner products of
    /// the (dim+1)-dimensional ALSH embedding — same accounting as the
    /// training-time selector.
    pub fn hash_mults(&self) -> u64 {
        (self.cfg.k * self.cfg.l * (self.family.dim() + 1)) as u64
    }

    /// Probe + rank the active set for query `q` into `out` (at most
    /// `budget` ids). Returns the hashing multiplication cost. Identical
    /// collect/rank semantics to [`LayerTables::query_prehashed`]; RNG for
    /// crowded buckets is derived from the fingerprints (see module docs).
    pub fn query(
        &self,
        q: &[f32],
        budget: usize,
        scratch: &mut FrozenQueryScratch,
        out: &mut Vec<u32>,
    ) -> u64 {
        out.clear();
        scratch.fps.clear();
        scratch.fps.resize(self.cfg.l, 0);
        self.family.hash_query(q, &mut scratch.fps);
        if budget == 0 || self.n_nodes == 0 {
            return self.hash_mults();
        }
        let mut rng = self.derived_rng(&scratch.fps);
        // Reclaim the fps buffer so probe_prehashed can borrow the rest of
        // the scratch mutably alongside it.
        let fps = std::mem::take(&mut scratch.fps);
        self.probe_prehashed(&fps, budget, scratch, &mut rng, out);
        scratch.fps = fps;
        self.hash_mults()
    }

    /// Probe + rank a query whose fingerprints were already computed (the
    /// shared batched execution core hashes whole micro-batches in one
    /// pass, then probes per sample through this). Same collect +
    /// counting-select core as the training-time
    /// [`LayerTables::query_prehashed`] — one implementation, so training
    /// and serving can never disagree on the ranking algorithm — followed
    /// by the deterministic empty-result fallback (rare hash miss on small
    /// layers; the RNG must be the fingerprint-derived one so the fallback
    /// stays worker-order independent).
    pub(crate) fn probe_prehashed(
        &self,
        fps: &[u32],
        budget: usize,
        scratch: &mut FrozenQueryScratch,
        rng: &mut Pcg64,
        out: &mut Vec<u32>,
    ) {
        out.clear();
        if budget == 0 || self.n_nodes == 0 {
            return;
        }
        let FrozenQueryScratch {
            stamp,
            counts,
            query_epoch,
            candidates,
            probe_scratch,
            addrs,
            gens,
            ..
        } = scratch;
        probe_and_rank(ProbeScratch {
            cfg: self.cfg,
            tables: &self.tables,
            n_nodes: self.n_nodes,
            fps,
            budget,
            stamp,
            counts,
            query_epoch,
            gens,
            probe_scratch,
            addrs,
            candidates,
            rng: &mut *rng,
            out: &mut *out,
        });
        if out.is_empty() {
            out.extend(rng.sample_indices(self.n_nodes, budget.min(4)));
        }
    }

    /// Per-query RNG: fingerprint-derived, so identical queries get
    /// identical sampling decisions on every worker (crate-visible for the
    /// shared batched execution core's frozen backend).
    pub(crate) fn derived_rng(&self, fps: &[u32]) -> Pcg64 {
        let mut acc = 0x5EED_F0E1_7AB1_E5u64;
        for &fp in fps {
            acc ^= fp as u64;
            acc = splitmix64(&mut acc);
        }
        Pcg64::new(acc, 0xF07E_11)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matrix::Matrix;

    fn live_tables(n: usize, d: usize, seed: u64, cfg: LshConfig) -> (Matrix, LayerTables) {
        let mut rng = Pcg64::seeded(seed);
        let w = Matrix::from_fn(n, d, |_, _| rng.gaussian() * 0.3);
        let lt = LayerTables::build(&w, cfg, &mut rng);
        (w, lt)
    }

    #[test]
    fn frozen_query_matches_live_when_rng_is_unused() {
        // With no crowded buckets and non-empty results, the training-time
        // query never touches its RNG, so the frozen path must reproduce it
        // exactly.
        let cfg = LshConfig { k: 6, l: 5, ..Default::default() };
        let (_, mut live) = live_tables(120, 16, 3, cfg);
        let frozen = FrozenLayerTables::freeze(&live);
        let mut scratch = FrozenQueryScratch::new();
        let mut rng = Pcg64::seeded(99);
        for t in 0..10 {
            let q: Vec<f32> = (0..16).map(|j| ((t * 16 + j) as f32 * 0.23).sin()).collect();
            let (mut a, mut b) = (Vec::new(), Vec::new());
            live.query(&q, 12, &mut rng, &mut a);
            frozen.query(&q, 12, &mut scratch, &mut b);
            assert_eq!(a, b, "query {t}");
        }
    }

    #[test]
    fn frozen_query_is_reproducible_across_scratches() {
        let cfg = LshConfig { k: 4, l: 6, ..Default::default() };
        let (_, live) = live_tables(300, 24, 7, cfg);
        let frozen = FrozenLayerTables::freeze(&live);
        let q: Vec<f32> = (0..24).map(|j| (j as f32 * 0.31).cos()).collect();
        let mut s1 = FrozenQueryScratch::new();
        let mut s2 = FrozenQueryScratch::new();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        frozen.query(&q, 30, &mut s1, &mut a);
        // Interleave an unrelated query on s2 first: results must not
        // depend on scratch history.
        let other: Vec<f32> = (0..24).map(|j| (j as f32 * 0.77).sin()).collect();
        let mut tmp = Vec::new();
        frozen.query(&other, 30, &mut s2, &mut tmp);
        frozen.query(&q, 30, &mut s2, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn freeze_preserves_buckets_and_family() {
        let cfg = LshConfig::default();
        let (_, live) = live_tables(80, 12, 11, cfg);
        let frozen = FrozenLayerTables::freeze(&live);
        assert_eq!(frozen.tables(), live.tables());
        assert_eq!(frozen.family().max_norm(), live.family().max_norm());
        assert_eq!(frozen.n_nodes(), 80);
    }

    #[test]
    fn refreeze_delta_shares_when_unmutated_and_refreezes_after_mutation() {
        let cfg = LshConfig { k: 5, l: 3, ..Default::default() };
        let (mut w, mut live) = live_tables(60, 8, 17, cfg);
        let prev = FrozenLayerTables::freeze(&live);
        // Nothing mutated since the freeze: the delta path shares every
        // bucket and the fingerprint blocks by Arc.
        let again = FrozenLayerTables::refreeze_delta(&live, &prev);
        assert_eq!(again.frozen_stamp(), prev.frozen_stamp());
        for (a, b) in again.tables().iter().zip(prev.tables()) {
            assert_eq!(a.shared_buckets_with(b), 1 << cfg.k);
            assert!(a.shares_fingerprints_with(b));
        }
        // A rehash invalidates the base: the re-freeze is a fresh one.
        let mut rng = Pcg64::seeded(18);
        for v in w.row_mut(9) {
            *v = -*v;
        }
        assert!(!live.rehash_nodes(&w, &[9], &mut rng));
        let next = FrozenLayerTables::refreeze_delta(&live, &prev);
        assert_eq!(next.tables(), live.tables());
        assert_ne!(next.frozen_stamp(), prev.frozen_stamp());
    }

    #[test]
    fn from_parts_validates_shape() {
        let cfg = LshConfig { k: 6, l: 5, ..Default::default() };
        let (_, live) = live_tables(40, 8, 13, cfg);
        let ok = FrozenLayerTables::from_parts(
            cfg,
            live.family().clone(),
            live.tables().to_vec(),
            40,
        );
        assert!(ok.is_ok());
        let short = live.tables()[..4].to_vec();
        assert!(FrozenLayerTables::from_parts(cfg, live.family().clone(), short, 40).is_err());
    }
}
