//! Locality-sensitive hashing for Maximum Inner Product Search — the
//! search substrate the paper builds on (§4.3, §5): signed random
//! projections, the asymmetric MIPS transform, O(1)-update hash tables,
//! multi-probe, and the per-layer (K, L) table stack.

pub mod alsh;
pub mod family;
pub mod frozen;
pub mod layered;
pub mod multiprobe;
pub mod sharded;
pub mod sparse_proj;
pub mod srp;
pub mod table;

pub use alsh::AlshMips;
pub use family::LshFamily;
pub use frozen::{FrozenLayerTables, FrozenQueryScratch};
pub use layered::{LayerTables, LshConfig};
pub use sharded::{LayerTableStack, ShardedFrozenTables, ShardedLayerTables};
pub use sparse_proj::SparseSrpHash;
pub use srp::SrpHash;
pub use table::HashTable;
