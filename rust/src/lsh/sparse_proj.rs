//! Very sparse random projections (Achlioptas 2001; Li, Hastie & Church
//! 2006) — the paper's §5.5 cites these as "techniques to further reduce
//! this hashing cost" [1, 23]. Each projection entry is
//!
//!   +sqrt(s) with prob 1/(2s),  −sqrt(s) with prob 1/(2s),  0 otherwise
//!
//! so a hash bit costs ~d/s multiplications instead of d. With s = 3 the
//! projection is provably JL-preserving; Li et al. push s to sqrt(d).
//! Used as a drop-in replacement for the gaussian SRP in an ablation
//! (benches/micro.rs) — same (K, L) semantics, ~s× cheaper hashing.

use crate::lsh::family::LshFamily;
use crate::util::rng::Pcg64;

/// One projection row stored sparsely: (index, ±sqrt(s)) pairs.
#[derive(Clone, Debug)]
struct SparseRow {
    idx: Vec<u32>,
    val: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct SparseSrpHash {
    k: usize,
    l: usize,
    dim: usize,
    s: usize,
    rows: Vec<SparseRow>,
}

impl SparseSrpHash {
    /// `s` is the sparsity factor (expected non-zeros per row = dim/s).
    pub fn new(dim: usize, k: usize, l: usize, s: usize, rng: &mut Pcg64) -> Self {
        assert!(k >= 1 && k <= 32 && l >= 1 && s >= 1);
        let magnitude = (s as f32).sqrt();
        let rows = (0..k * l)
            .map(|_| {
                let mut idx = Vec::new();
                let mut val = Vec::new();
                for j in 0..dim {
                    // P(nonzero) = 1/s, then sign is a fair coin.
                    if rng.below(s as u32) == 0 {
                        idx.push(j as u32);
                        val.push(if rng.bernoulli(0.5) { magnitude } else { -magnitude });
                    }
                }
                // Degenerate all-zero row: force one entry so the bit is
                // not constant.
                if idx.is_empty() {
                    idx.push(rng.below(dim as u32));
                    val.push(magnitude);
                }
                SparseRow { idx, val }
            })
            .collect();
        SparseSrpHash { k, l, dim, s, rows }
    }

    #[inline]
    fn bit(&self, row: &SparseRow, x: &[f32]) -> bool {
        let mut acc = 0.0f32;
        for (&j, &v) in row.idx.iter().zip(&row.val) {
            acc += x[j as usize] * v;
        }
        acc >= 0.0
    }

    /// Expected multiplications per full K·L fingerprint set.
    pub fn mults_per_hash(&self) -> u64 {
        self.rows.iter().map(|r| r.idx.len() as u64).sum()
    }

    /// Dense-SRP equivalent cost (for the ablation's speedup figure).
    pub fn dense_equivalent_mults(&self) -> u64 {
        (self.k * self.l * self.dim) as u64
    }

    pub fn sparsity_factor(&self) -> usize {
        self.s
    }
}

impl LshFamily for SparseSrpHash {
    fn k(&self) -> usize {
        self.k
    }
    fn l(&self) -> usize {
        self.l
    }
    fn dim(&self) -> usize {
        self.dim
    }

    fn hash_data(&self, x: &[f32], out: &mut [u32]) {
        debug_assert_eq!(out.len(), self.l);
        for (j, o) in out.iter_mut().enumerate() {
            let mut fp = 0u32;
            for i in 0..self.k {
                fp = (fp << 1) | self.bit(&self.rows[j * self.k + i], x) as u32;
            }
            *o = fp;
        }
    }

    fn hash_query(&self, q: &[f32], out: &mut [u32]) {
        self.hash_data(q, out); // symmetric
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_is_roughly_dim_over_s() {
        let mut rng = Pcg64::seeded(1);
        let f = SparseSrpHash::new(900, 6, 5, 3, &mut rng);
        let per_row = f.mults_per_hash() as f64 / 30.0;
        assert!(
            (per_row - 300.0).abs() < 60.0,
            "expected ~dim/s = 300 nonzeros per row, got {per_row}"
        );
        assert!(f.mults_per_hash() * 2 < f.dense_equivalent_mults());
    }

    #[test]
    fn fingerprints_fit_k_bits_and_are_deterministic() {
        let mut rng = Pcg64::seeded(2);
        let f = SparseSrpHash::new(64, 6, 4, 3, &mut rng);
        let x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
        let a = f.data_fingerprints(&x);
        let b = f.query_fingerprints(&x);
        assert_eq!(a, b);
        assert!(a.iter().all(|&fp| fp < 64));
    }

    #[test]
    fn scale_invariance_holds() {
        let mut rng = Pcg64::seeded(3);
        let f = SparseSrpHash::new(32, 5, 3, 3, &mut rng);
        let x: Vec<f32> = (0..32).map(|_| rng.gaussian()).collect();
        let x2: Vec<f32> = x.iter().map(|v| v * 4.0).collect();
        assert_eq!(f.data_fingerprints(&x), f.data_fingerprints(&x2));
    }

    #[test]
    fn collision_monotone_in_similarity() {
        // Same statistical property as dense SRP, at a fraction of the cost.
        let mut rng = Pcg64::seeded(4);
        let dim = 48;
        let (mut close_coll, mut far_coll) = (0usize, 0usize);
        let trials = 300;
        for t in 0..trials {
            let f = SparseSrpHash::new(dim, 1, 6, 3, &mut Pcg64::seeded(5000 + t as u64));
            let x: Vec<f32> = (0..dim).map(|_| rng.gaussian()).collect();
            let close: Vec<f32> = x.iter().map(|v| v + 0.1 * rng.gaussian()).collect();
            let far: Vec<f32> = (0..dim).map(|_| rng.gaussian()).collect();
            let fx = f.data_fingerprints(&x);
            close_coll += fx.iter().zip(f.data_fingerprints(&close)).filter(|(a, b)| **a == *b).count();
            far_coll += fx.iter().zip(f.data_fingerprints(&far)).filter(|(a, b)| **a == *b).count();
        }
        assert!(
            close_coll > far_coll + trials / 2,
            "close {close_coll} vs far {far_coll}"
        );
    }

    #[test]
    fn no_constant_bits_from_empty_rows() {
        // Even at extreme sparsity every row has at least one entry.
        let mut rng = Pcg64::seeded(5);
        let f = SparseSrpHash::new(8, 4, 2, 1000, &mut rng);
        assert!(f.mults_per_hash() >= 8);
    }
}
