//! Per-layer (K, L) hash table stack — the data structure at the core of
//! the paper (Algorithm 1): `HT_l = constructHashTable(W_l, HF_l)`, queried
//! each forward pass for the active set and re-organized after each
//! gradient update.

use crate::lsh::alsh::{max_row_norm, AlshMips};
use crate::lsh::family::LshFamily;
use crate::lsh::multiprobe::ProbeGen;
use crate::lsh::table::{HashTable, DEFAULT_CROWDED_LIMIT};
use crate::obs::health::{HealthTally, TableHealth};
use crate::tensor::matrix::Matrix;
use crate::tensor::vecops::norm;
use crate::util::rng::Pcg64;

/// Tunables for table construction and querying (paper §5.5 defaults:
/// K=6, L=5, ~10 probes per table).
#[derive(Clone, Copy, Debug)]
pub struct LshConfig {
    pub k: usize,
    pub l: usize,
    /// Max buckets probed per table (multi-probe budget).
    pub probes_per_table: usize,
    /// Crowded-bucket sub-sampling limit.
    pub crowded_limit: usize,
    /// Cheap re-ranking (paper §5.4 [37]): collect `rerank_factor x budget`
    /// candidates, score them exactly, keep the top budget. 0 disables.
    pub rerank_factor: usize,
    /// Lazy maintenance (§Perf): rehash each updated row with this
    /// probability instead of always. Stale entries are bounded by the
    /// per-epoch full rebuild. 1.0 = always (paper's literal Algorithm 1).
    pub rehash_probability: f32,
}

impl Default for LshConfig {
    fn default() -> Self {
        LshConfig {
            k: 6,
            l: 5,
            probes_per_table: 10,
            crowded_limit: DEFAULT_CROWDED_LIMIT,
            rerank_factor: 0,
            rehash_probability: 1.0,
        }
    }
}

/// L hash tables over one layer's neurons.
pub struct LayerTables {
    cfg: LshConfig,
    family: AlshMips,
    tables: Vec<HashTable>,
    n_nodes: usize,
    /// Scratch: membership stamp per node for de-duplicating the union
    /// across tables without a hash set. `stamp[i] == query_epoch` means
    /// node i already collected for the current query.
    stamp: Vec<u32>,
    /// Scratch: per-node collision multiplicity for the current query —
    /// the empirical estimate of the Theorem-1 retrieval probability
    /// 1-(1-p^K)^L, used to rank candidates.
    counts: Vec<u8>,
    query_epoch: u32,
    /// Reusable query scratch (fingerprints, candidate union, bucket probe
    /// buffer, one probe generator per table) so repeated queries — in
    /// particular the batched selection path — allocate nothing.
    fps_scratch: Vec<u32>,
    candidates: Vec<u32>,
    probe_scratch: Vec<u32>,
    gens: Vec<ProbeGen>,
    /// Scratch for the batched hashing pass (ALSH query embeddings of a
    /// whole minibatch, `B × (dim+1)`).
    embed_scratch: Vec<f32>,
    /// Scratch: per-table next bucket address for the current probe depth
    /// (u32::MAX = generator exhausted) — lets the probe loop prefetch
    /// every table's bucket before scanning any of them.
    addrs: Vec<u32>,
    /// Monotonic mutation counter: bumped by every rehash that touches the
    /// tables and by every rebuild. A frozen view records the stamp it was
    /// taken at; delta re-freezing compares stamps to decide whether the
    /// previous epoch's frozen tables can be reused as-is.
    mutation_stamp: u64,
    /// Count of full rebuilds (norm overflow) — surfaced in metrics.
    pub rebuilds: usize,
    /// Hashes computed since construction (K·L per hashed vector) — the
    /// paper's "30 hash computations" accounting.
    pub hash_ops: u64,
    /// Table-health accounting (activation counters, rebuild age, recall
    /// samples) — fed by the selection path when telemetry is on.
    health: HealthTally,
}

impl LayerTables {
    /// Build tables over the rows of `weights` (row = neuron weight vector).
    pub fn build(weights: &Matrix, cfg: LshConfig, rng: &mut Pcg64) -> Self {
        let n_nodes = weights.rows();
        let max_norm = max_row_norm((0..n_nodes).map(|r| weights.row(r)));
        let family = AlshMips::new(weights.cols(), cfg.k, cfg.l, max_norm, rng);
        let mut lt = LayerTables {
            cfg,
            family,
            tables: (0..cfg.l).map(|_| HashTable::new(cfg.k, n_nodes)).collect(),
            n_nodes,
            stamp: vec![0; n_nodes],
            counts: vec![0; n_nodes],
            query_epoch: 0,
            fps_scratch: Vec::new(),
            candidates: Vec::new(),
            probe_scratch: Vec::new(),
            gens: Vec::new(),
            embed_scratch: Vec::new(),
            addrs: Vec::new(),
            mutation_stamp: 0,
            rebuilds: 0,
            hash_ops: 0,
            health: HealthTally::new(n_nodes),
        };
        lt.insert_all(weights);
        lt
    }

    fn insert_all(&mut self, weights: &Matrix) {
        let mut fps = vec![0u32; self.cfg.l];
        for id in 0..self.n_nodes {
            self.family.hash_data(weights.row(id), &mut fps);
            self.hash_ops += (self.cfg.k * self.cfg.l) as u64;
            for (t, &fp) in self.tables.iter_mut().zip(&fps) {
                t.insert(id as u32, fp);
            }
        }
    }

    pub fn config(&self) -> LshConfig {
        self.cfg
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Query the active set for input `q`.
    ///
    /// Two phases (both sub-linear in the layer width):
    /// 1. **Collect**: union of multi-probed buckets across all L tables,
    ///    counting each node's collision multiplicity. Home buckets are
    ///    probed first, then Hamming-distance-1 buckets, etc. The whole
    ///    probe budget is consumed (bucket scanning costs no
    ///    multiplications — only the K·L query hashes do), because the
    ///    multiplicity signal needs every probe.
    /// 2. **Rank**: keep the `budget` candidates with the highest
    ///    multiplicity (counting-sort over counts 1..=L·probes). The
    ///    multiplicity is the empirical estimate of the Theorem-1
    ///    retrieval probability 1-(1-p^K)^L — nodes colliding in many
    ///    tables almost surely have high inner products. Ties resolve in
    ///    collection order (closer probes first), preserving the
    ///    closest-bucket preference.
    pub fn query(&mut self, q: &[f32], budget: usize, rng: &mut Pcg64, out: &mut Vec<u32>) {
        out.clear();
        if budget == 0 || self.n_nodes == 0 {
            return;
        }
        let mut fps = std::mem::take(&mut self.fps_scratch);
        self.hash_query_fps(q, &mut fps);
        self.query_prehashed(&fps, budget, rng, out);
        self.fps_scratch = fps;
    }

    /// Compute the K·L query fingerprints into `fps` (one per table) and
    /// account the hash cost. Split out of [`LayerTables::query`] so the
    /// batched selection path can hash every sample of a minibatch in one
    /// pass before probing.
    pub fn hash_query_fps(&mut self, q: &[f32], fps: &mut Vec<u32>) {
        fps.clear();
        fps.resize(self.cfg.l, 0);
        self.family.hash_query(q, fps);
        self.hash_ops += (self.cfg.k * self.cfg.l) as u64;
    }

    /// One-pass fingerprint hashing for a whole minibatch of densified
    /// queries (rows of `q_plane`): all `bsz × L` fingerprints land in
    /// `fps_plane` (row-major), bit-for-bit identical to per-sample
    /// [`LayerTables::hash_query_fps`], while the K·L projection rows are
    /// traversed once per batch instead of once per sample. This is the
    /// training-side backend of `exec::TableView::hash_batch`.
    pub fn hash_query_batch(&mut self, q_plane: &[f32], bsz: usize, fps_plane: &mut [u32]) {
        debug_assert_eq!(fps_plane.len(), bsz * self.cfg.l);
        self.family.hash_queries_batch(q_plane, bsz, &mut self.embed_scratch, fps_plane);
        self.hash_ops += (bsz * self.cfg.k * self.cfg.l) as u64;
    }

    /// Probe + rank for a query whose fingerprints were already computed.
    /// Uses the per-instance scratch buffers, so repeated calls allocate
    /// nothing. Identical results to [`LayerTables::query`].
    pub fn query_prehashed(
        &mut self,
        fps: &[u32],
        budget: usize,
        rng: &mut Pcg64,
        out: &mut Vec<u32>,
    ) {
        out.clear();
        if budget == 0 || self.n_nodes == 0 {
            return;
        }
        let Self {
            cfg,
            tables,
            n_nodes,
            stamp,
            counts,
            query_epoch,
            candidates,
            probe_scratch,
            gens,
            addrs,
            ..
        } = self;
        probe_and_rank(ProbeScratch {
            cfg: *cfg,
            tables,
            n_nodes: *n_nodes,
            fps,
            budget,
            stamp,
            counts,
            query_epoch,
            gens,
            probe_scratch,
            addrs,
            candidates,
            rng,
            out,
        });
    }

    /// Re-hash a set of updated nodes (after a gradient step touched their
    /// weights). Returns true if a full rebuild was required because some
    /// weight norm outgrew the ALSH scaling constant M.
    pub fn rehash_nodes(&mut self, weights: &Matrix, ids: &[u32], rng: &mut Pcg64) -> bool {
        // Check norm overflow first — rebuild re-hashes everything anyway.
        for &id in ids {
            if !self.family.fits(norm(weights.row(id as usize))) {
                self.rebuild(weights, rng);
                return true;
            }
        }
        if ids.is_empty() {
            return false;
        }
        // Even a same-bucket fingerprint refresh writes table state, so any
        // non-empty rehash invalidates frozen views taken before it.
        self.mutation_stamp = self.mutation_stamp.wrapping_add(1);
        let mut fps = vec![0u32; self.cfg.l];
        for &id in ids {
            self.family.hash_data(weights.row(id as usize), &mut fps);
            self.hash_ops += (self.cfg.k * self.cfg.l) as u64;
            for (t, &fp) in self.tables.iter_mut().zip(&fps) {
                t.update(id, fp);
            }
        }
        false
    }

    /// Full rebuild: new M (with headroom), fresh projections, re-insert all.
    pub fn rebuild(&mut self, weights: &Matrix, rng: &mut Pcg64) {
        let max_norm = max_row_norm((0..self.n_nodes).map(|r| weights.row(r)));
        self.family = AlshMips::new(weights.cols(), self.cfg.k, self.cfg.l, max_norm, rng);
        self.tables = (0..self.cfg.l).map(|_| HashTable::new(self.cfg.k, self.n_nodes)).collect();
        self.insert_all(weights);
        self.mutation_stamp = self.mutation_stamp.wrapping_add(1);
        self.rebuilds += 1;
        self.health.reset_rebuild_age();
        crate::obs::events::emit(
            crate::obs::EventKind::Rebuild,
            "tables",
            self.rebuilds as u64,
            "rebuild",
        );
    }

    /// Diagnostics: per-table occupancy histograms.
    pub fn bucket_sizes(&self) -> Vec<Vec<usize>> {
        self.tables.iter().map(|t| t.bucket_sizes()).collect()
    }

    /// Borrow the underlying ALSH family (for equivalence tests).
    pub fn family(&self) -> &AlshMips {
        &self.family
    }

    /// Read-only view of the per-table bucket structures — what the frozen
    /// serving view and snapshot serialization consume.
    pub fn tables(&self) -> &[HashTable] {
        &self.tables
    }

    /// The mutation counter a frozen view records at freeze time: if it is
    /// unchanged at the next publish, the previous frozen tables are still
    /// exact and can be shared instead of re-frozen.
    pub fn mutation_stamp(&self) -> u64 {
        self.mutation_stamp
    }

    /// The running health counters (selection-time fold-in target).
    pub fn health_tally(&self) -> &HealthTally {
        &self.health
    }

    /// Computed health snapshot: occupancy stats read live from the
    /// buckets, combined with the running tally.
    pub fn health_snapshot(&self) -> TableHealth {
        TableHealth::compute(&self.bucket_sizes(), self.rebuilds as u64, &self.health)
    }
}

/// Everything one probe-and-rank pass needs: the immutable table state,
/// the query, and every scratch buffer — bundled so the training-time
/// (`&mut LayerTables`) and frozen serving (`&FrozenLayerTables` +
/// external per-thread scratch) paths share one implementation instead of
/// two drifting copies.
pub(crate) struct ProbeScratch<'a> {
    pub cfg: LshConfig,
    pub tables: &'a [HashTable],
    pub n_nodes: usize,
    pub fps: &'a [u32],
    pub budget: usize,
    pub stamp: &'a mut Vec<u32>,
    pub counts: &'a mut Vec<u8>,
    pub query_epoch: &'a mut u32,
    pub gens: &'a mut Vec<ProbeGen>,
    pub probe_scratch: &'a mut Vec<u32>,
    pub addrs: &'a mut Vec<u32>,
    pub candidates: &'a mut Vec<u32>,
    pub rng: &'a mut Pcg64,
    pub out: &'a mut Vec<u32>,
}

/// The collect + counting-select core behind every table query (see
/// [`LayerTables::query`] for the algorithm description). Callers clear
/// `out` and handle the `budget == 0` / empty-table guards; this fills
/// `out` with at most `budget` distinct node ids.
pub(crate) fn probe_and_rank(s: ProbeScratch<'_>) {
    let ProbeScratch {
        cfg,
        tables,
        n_nodes,
        fps,
        budget,
        stamp,
        counts,
        query_epoch,
        gens,
        probe_scratch,
        addrs,
        candidates,
        rng,
        out,
    } = s;
    // Lazy sizing: the training tables pre-size these at build, the frozen
    // per-thread scratch grows to the widest layer it has served.
    if stamp.len() < n_nodes {
        stamp.resize(n_nodes, 0);
        counts.resize(n_nodes, 0);
    }
    *query_epoch = query_epoch.wrapping_add(1);
    if *query_epoch == 0 {
        // Stamp wrap: reset (happens once per 2^32 queries).
        stamp.iter_mut().for_each(|v| *v = u32::MAX);
        *query_epoch = 1;
    }
    candidates.clear();
    // Round-robin probe depth across tables: probe the home bucket of
    // every table first, then distance-1 buckets, etc., so the union is
    // balanced across tables.
    if gens.len() < fps.len() {
        gens.resize_with(fps.len(), ProbeGen::idle);
    }
    for (g, &fp) in gens.iter_mut().zip(fps) {
        g.reset(fp, cfg.k, cfg.probes_per_table);
    }
    for _depth in 0..cfg.probes_per_table {
        // Pass 1: advance every generator to its next bucket address
        // (u32::MAX = exhausted; real addresses are K ≤ 16 bits) and, with
        // `simd`, prefetch each address's bucket id array — by the time
        // pass 2 scans a bucket, the line is usually already in cache.
        addrs.clear();
        for g in gens.iter_mut().take(fps.len()) {
            addrs.push(g.next().unwrap_or(u32::MAX));
        }
        if addrs.iter().all(|&a| a == u32::MAX) {
            break;
        }
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        for (ti, &addr) in addrs.iter().enumerate() {
            if addr != u32::MAX {
                tables[ti].prefetch_bucket(addr);
            }
        }
        // Pass 2: probe in table order — same visit order and RNG
        // consumption as the single-pass loop this replaces, so results
        // are bit-identical with or without the prefetch pass.
        for (ti, &addr) in addrs.iter().enumerate() {
            if addr == u32::MAX {
                continue;
            }
            probe_scratch.clear();
            tables[ti].probe_into(addr, cfg.crowded_limit, rng, probe_scratch);
            for &id in probe_scratch.iter() {
                if stamp[id as usize] != *query_epoch {
                    stamp[id as usize] = *query_epoch;
                    counts[id as usize] = 1;
                    candidates.push(id);
                } else {
                    counts[id as usize] = counts[id as usize].saturating_add(1);
                }
            }
        }
    }

    if candidates.len() <= budget {
        out.extend_from_slice(candidates);
        return;
    }
    // Counting-select: take candidates by descending multiplicity.
    let max_count = candidates.iter().map(|&id| counts[id as usize]).max().unwrap_or(1);
    for want in (1..=max_count).rev() {
        for &id in candidates.iter() {
            if counts[id as usize] == want {
                out.push(id);
                if out.len() >= budget {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::vecops::dot;

    fn weights(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        Matrix::from_fn(n, d, |_, _| rng.gaussian() * 0.3)
    }

    #[test]
    fn build_inserts_every_node_in_every_table() {
        let w = weights(50, 16, 1);
        let mut rng = Pcg64::seeded(2);
        let lt = LayerTables::build(&w, LshConfig { k: 6, l: 5, ..Default::default() }, &mut rng);
        for sizes in lt.bucket_sizes() {
            assert_eq!(sizes.iter().sum::<usize>(), 50);
        }
    }

    #[test]
    fn query_returns_distinct_ids_within_budget() {
        let w = weights(200, 16, 3);
        let mut rng = Pcg64::seeded(4);
        let mut lt = LayerTables::build(&w, LshConfig::default(), &mut rng);
        let q: Vec<f32> = (0..16).map(|_| rng.gaussian()).collect();
        let mut out = Vec::new();
        lt.query(&q, 20, &mut rng, &mut out);
        assert!(out.len() <= 20);
        let mut s = out.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), out.len(), "ids must be distinct");
        assert!(out.iter().all(|&i| (i as usize) < 200));
    }

    #[test]
    fn query_prefers_high_inner_product_nodes() {
        // Recall test: the active set should be enriched in true top nodes.
        let n = 500;
        let d = 32;
        let w = weights(n, d, 5);
        let mut rng = Pcg64::seeded(6);
        let mut lt = LayerTables::build(
            &w,
            LshConfig { k: 6, l: 8, probes_per_table: 8, ..Default::default() },
            &mut rng,
        );
        let mut hits = 0usize;
        let mut total = 0usize;
        for _ in 0..30 {
            let q: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
            let mut out = Vec::new();
            lt.query(&q, 50, &mut rng, &mut out);
            if out.is_empty() {
                continue;
            }
            // True top-50 by inner product.
            let ips: Vec<f32> = (0..n).map(|i| dot(w.row(i), &q)).collect();
            let top = crate::tensor::vecops::top_k_indices(&ips, 50);
            let topset: std::collections::HashSet<u32> = top.into_iter().collect();
            hits += out.iter().filter(|id| topset.contains(id)).count();
            total += out.len();
        }
        let precision = hits as f64 / total as f64;
        // Random selection would land at 50/500 = 10%. Unstructured gaussian
        // weights are the worst case (near-orthogonal vectors); real trained
        // layers separate much harder — see planted test below.
        assert!(precision > 0.15, "active-set precision {precision:.3} barely above chance");
    }

    #[test]
    fn query_retrieves_planted_high_activation_nodes() {
        // Plant 5 nodes aligned with the query among 495 random ones: the
        // active set must contain almost all of them (the regime the paper
        // relies on — hot neurons have genuinely high inner products).
        let n = 500;
        let d = 32;
        let mut rng = Pcg64::seeded(21);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
        let qn = norm(&q);
        let mut w = weights(n, d, 22);
        // Plant at a norm comparable to the layer max (≈0.3·√32≈1.7): a hot
        // neuron is hot because of norm × alignment; the ALSH embedding
        // preserves exactly that product.
        for planted in 0..5 {
            let row = w.row_mut(planted);
            for (wv, qv) in row.iter_mut().zip(&q) {
                *wv = qv / qn * 1.6 + 0.02 * rng.gaussian();
            }
        }
        let mut lt = LayerTables::build(
            &w,
            LshConfig { k: 6, l: 8, probes_per_table: 8, ..Default::default() },
            &mut rng,
        );
        let mut out = Vec::new();
        lt.query(&q, 50, &mut rng, &mut out);
        let found = (0..5u32).filter(|id| out.contains(id)).count();
        assert!(found >= 4, "only {found}/5 planted nodes retrieved: {out:?}");
    }

    #[test]
    fn prehashed_query_matches_query() {
        let w = weights(120, 16, 31);
        let mut rng_a = Pcg64::seeded(32);
        let mut rng_b = Pcg64::seeded(32);
        let mut lt_a = LayerTables::build(&w, LshConfig::default(), &mut rng_a);
        let mut lt_b = LayerTables::build(&w, LshConfig::default(), &mut rng_b);
        let q: Vec<f32> = (0..16).map(|i| (i as f32 * 0.31).cos()).collect();
        let (mut out_a, mut out_b) = (Vec::new(), Vec::new());
        lt_a.query(&q, 15, &mut rng_a, &mut out_a);
        let mut fps = Vec::new();
        lt_b.hash_query_fps(&q, &mut fps);
        lt_b.query_prehashed(&fps, 15, &mut rng_b, &mut out_b);
        assert_eq!(out_a, out_b, "split query path must match the one-shot path");
        assert_eq!(lt_a.hash_ops, lt_b.hash_ops);
    }

    #[test]
    fn batched_hash_matches_per_sample_and_accounts_hash_ops() {
        let w = weights(80, 12, 41);
        let mut rng = Pcg64::seeded(42);
        let cfg = LshConfig { k: 5, l: 4, ..Default::default() };
        let mut lt = LayerTables::build(&w, cfg, &mut rng);
        let bsz = 5;
        let plane: Vec<f32> = (0..bsz * 12).map(|i| ((i as f32) * 0.37).sin()).collect();
        let base = lt.hash_ops;
        let mut fps_plane = vec![0u32; bsz * cfg.l];
        lt.hash_query_batch(&plane, bsz, &mut fps_plane);
        assert_eq!(lt.hash_ops, base + (bsz * cfg.k * cfg.l) as u64);
        let mut fps = Vec::new();
        for s in 0..bsz {
            lt.hash_query_fps(&plane[s * 12..(s + 1) * 12], &mut fps);
            assert_eq!(&fps_plane[s * cfg.l..(s + 1) * cfg.l], fps.as_slice(), "sample {s}");
        }
    }

    #[test]
    fn rehash_moves_changed_node() {
        let mut w = weights(20, 8, 7);
        let mut rng = Pcg64::seeded(8);
        let mut lt = LayerTables::build(&w, LshConfig { k: 8, l: 3, ..Default::default() }, &mut rng);
        // Flip node 0's weights entirely (within norm budget).
        for v in w.row_mut(0) {
            *v = -*v;
        }
        assert!(!lt.rehash_nodes(&w, &[0], &mut rng), "no rebuild needed for same-norm change");
        // Node must still be present exactly once per table.
        for sizes in lt.bucket_sizes() {
            assert_eq!(sizes.iter().sum::<usize>(), 20);
        }
    }

    #[test]
    fn norm_overflow_triggers_rebuild() {
        let mut w = weights(20, 8, 9);
        let mut rng = Pcg64::seeded(10);
        let mut lt = LayerTables::build(&w, LshConfig::default(), &mut rng);
        for v in w.row_mut(3) {
            *v *= 100.0;
        }
        assert!(lt.rehash_nodes(&w, &[3], &mut rng));
        assert_eq!(lt.rebuilds, 1);
        assert!(lt.family().fits(norm(w.row(3))));
        for sizes in lt.bucket_sizes() {
            assert_eq!(sizes.iter().sum::<usize>(), 20);
        }
    }

    #[test]
    fn zero_budget_returns_empty() {
        let w = weights(10, 8, 11);
        let mut rng = Pcg64::seeded(12);
        let mut lt = LayerTables::build(&w, LshConfig::default(), &mut rng);
        let mut out = vec![99];
        lt.query(&[0.5; 8], 0, &mut rng, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn hash_ops_accounting() {
        let w = weights(10, 8, 13);
        let mut rng = Pcg64::seeded(14);
        let cfg = LshConfig { k: 6, l: 5, ..Default::default() };
        let mut lt = LayerTables::build(&w, cfg, &mut rng);
        let after_build = lt.hash_ops;
        assert_eq!(after_build, 10 * 30, "K*L hashes per node at build");
        let mut out = Vec::new();
        lt.query(&[0.1; 8], 5, &mut rng, &mut out);
        assert_eq!(lt.hash_ops, after_build + 30, "one K*L query hash");
    }
}
