//! Per-shard LSH table stacks for sharded wide layers.
//!
//! A wide layer (10⁵–10⁶ nodes) is indexed as `S` independent
//! [`LayerTables`], one per block-contiguous shard of the
//! [`ShardedPlane`] mirror. Each shard owns its own ALSH family,
//! buckets, rebuild clock and weight-plane slice, so probe working sets
//! stay cache-resident and shard owners never touch each other's memory.
//! Selection hashes a batch once per shard, probes and ranks per shard
//! under a proportional budget split, and merges candidates back to
//! global ids with a single offset add (block layout makes a shard's id
//! range an interval).
//!
//! **S=1 parity contract:** with one shard, every code path here reduces
//! to the unsharded call sequence on a bit-identical weight copy — same
//! RNG draws in the same order (build, rehash, rebuild, fallback), same
//! fingerprints, same candidates. Pinned by the tests below and
//! `tests/sharding.rs`.

use crate::lsh::frozen::{FrozenLayerTables, FrozenQueryScratch};
use crate::lsh::layered::{LayerTables, LshConfig};
use crate::obs::health::{HealthTally, TableHealth};
use crate::tensor::matrix::Matrix;
use crate::tensor::sharded::{ShardMap, ShardedPlane};
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Split a layer budget across shards proportionally to the rows each
/// shard owns (floor division, remainder dealt one-per-shard from shard
/// 0). `S = 1` always yields `[budget]` — the parity-critical case.
pub fn split_budget(map: &ShardMap, budget: usize, out: &mut Vec<usize>) {
    out.clear();
    let n = map.n_rows();
    if n == 0 {
        out.resize(map.shards(), 0);
        return;
    }
    let mut used = 0usize;
    for s in 0..map.shards() {
        let share = budget * map.rows_in(s) / n;
        out.push(share);
        used += share;
    }
    let mut rem = budget - used;
    let mut s = 0usize;
    while rem > 0 {
        out[s] += 1;
        rem -= 1;
        s = (s + 1) % map.shards();
    }
}

/// Live (training-side) sharded table stack: `S` independent
/// [`LayerTables`] over the shard planes of a [`ShardedPlane`] mirror of
/// the layer's weight matrix. The mirror is synced row-wise from the
/// live weights on every `post_update` (the trainer hands the selector
/// the exact touched union per batch) and shard-wise before a rebuild,
/// so under Hogwild a shard's tables are never staler than one epoch
/// with respect to other workers' updates — the same staleness class as
/// `rehash_probability < 1`.
pub struct ShardedLayerTables {
    cfg: LshConfig,
    mirror: ShardedPlane,
    shards: Vec<LayerTables>,
    /// Stack-level health tally over *global* ids — the selection path
    /// folds merged active sets in here; per-shard rows slice it by the
    /// shard's id range.
    health: HealthTally,
    // Reusable scratch (selection allocates nothing in steady state).
    budget_split: Vec<usize>,
    fps_tmp: Vec<u32>,
    sub_out: Vec<u32>,
    rehash_subset: Vec<u32>,
    local_ids: Vec<Vec<u32>>,
}

impl ShardedLayerTables {
    /// Build per-shard tables over the rows of `weights`. Shards are
    /// built in shard order from one RNG stream; at `S = 1` this
    /// consumes `rng` exactly like [`LayerTables::build`] on `weights`.
    pub fn build(weights: &Matrix, cfg: LshConfig, shards: usize, rng: &mut Pcg64) -> Self {
        let mirror = ShardedPlane::from_matrix(weights, shards);
        let built: Vec<LayerTables> =
            (0..mirror.shards()).map(|s| LayerTables::build(mirror.plane(s), cfg, rng)).collect();
        let local_ids = vec![Vec::new(); mirror.shards()];
        ShardedLayerTables {
            cfg,
            health: HealthTally::new(mirror.n_rows()),
            budget_split: Vec::new(),
            fps_tmp: Vec::new(),
            sub_out: Vec::new(),
            rehash_subset: Vec::new(),
            local_ids,
            mirror,
            shards: built,
        }
    }

    pub fn config(&self) -> LshConfig {
        self.cfg
    }

    pub fn n_nodes(&self) -> usize {
        self.mirror.n_rows()
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn map(&self) -> &ShardMap {
        self.mirror.map()
    }

    pub fn shard(&self, s: usize) -> &LayerTables {
        &self.shards[s]
    }

    /// Stack-level (global-id) health counters.
    pub fn health_tally(&self) -> &HealthTally {
        &self.health
    }

    /// Total full rebuilds across all shards.
    pub fn rebuilds(&self) -> u64 {
        self.shards.iter().map(|t| t.rebuilds as u64).sum()
    }

    /// Total hash operations across all shards.
    pub fn hash_ops(&self) -> u64 {
        self.shards.iter().map(|t| t.hash_ops).sum()
    }

    /// One health row per shard: that shard's bucket occupancy and
    /// rebuild count, node statistics sliced from the stack tally by the
    /// shard's global-id range (O(active + shard buckets) each).
    pub fn health_rows(&self) -> Vec<TableHealth> {
        (0..self.shards.len())
            .map(|s| {
                TableHealth::compute_subset(
                    &self.shards[s].bucket_sizes(),
                    self.shards[s].rebuilds as u64,
                    &self.health,
                    self.mirror.map().range(s),
                )
            })
            .collect()
    }

    /// One-pass batched fingerprint hashing, one invocation per shard.
    /// Per-sample fingerprint layout in `fps_plane`:
    /// `[shard 0's L fps | shard 1's L fps | …]` (each shard hashes with
    /// its own ALSH family). At `S = 1` the layout and bits are exactly
    /// [`LayerTables::hash_query_batch`]'s.
    pub fn hash_batch_sharded(&mut self, q_plane: &[f32], bsz: usize, fps_plane: &mut [u32]) {
        let l = self.cfg.l;
        let s_count = self.shards.len();
        debug_assert_eq!(fps_plane.len(), bsz * l * s_count);
        let Self { shards, fps_tmp, .. } = self;
        for (s, shard) in shards.iter_mut().enumerate() {
            fps_tmp.clear();
            fps_tmp.resize(bsz * l, 0);
            shard.hash_query_batch(q_plane, bsz, fps_tmp);
            for b in 0..bsz {
                let dst = (b * s_count + s) * l;
                fps_plane[dst..dst + l].copy_from_slice(&fps_tmp[b * l..(b + 1) * l]);
            }
        }
    }

    /// Probe + rank one prehashed sample: split `budget` across shards,
    /// probe each shard at `share × collect_factor` (over-collection for
    /// §5.4 re-ranking happens per shard), and merge local ids back to
    /// global with the shard's base offset. Shards consume `rng` in
    /// shard order — at `S = 1` this is exactly one
    /// [`LayerTables::query_prehashed`] call at `budget × collect_factor`.
    ///
    /// Re-ranking and the global empty-result fallback are the caller's
    /// job (the `exec` backend), mirroring the unsharded live backend.
    pub fn probe_prehashed_sharded(
        &mut self,
        fps: &[u32],
        budget: usize,
        collect_factor: usize,
        rng: &mut Pcg64,
        out: &mut Vec<u32>,
    ) {
        out.clear();
        let l = self.cfg.l;
        debug_assert_eq!(fps.len(), l * self.shards.len());
        let Self { mirror, shards, budget_split, sub_out, .. } = self;
        split_budget(mirror.map(), budget, budget_split);
        for (s, shard) in shards.iter_mut().enumerate() {
            let share = budget_split[s] * collect_factor.max(1);
            if share == 0 {
                continue;
            }
            shard.query_prehashed(&fps[s * l..(s + 1) * l], share, rng, sub_out);
            let base = mirror.map().base(s) as u32;
            out.extend(sub_out.iter().map(|&id| id + base));
        }
    }

    /// Post-gradient maintenance: sync the touched rows into the mirror,
    /// draw the `rehash_probability` subset **in touched order** (one
    /// global RNG stream — at `S = 1` the exact draws the unsharded
    /// selector makes), partition it by owning shard and rehash each
    /// shard against its own plane in shard order.
    pub fn post_update(&mut self, weights: &Matrix, touched: &[u32], rng: &mut Pcg64) {
        if touched.is_empty() {
            return;
        }
        let Self { cfg, mirror, shards, rehash_subset, local_ids, .. } = self;
        mirror.sync_rows(weights, touched);
        rehash_subset.clear();
        let p = cfg.rehash_probability;
        if p >= 1.0 {
            rehash_subset.extend_from_slice(touched);
        } else {
            for &id in touched {
                if rng.bernoulli(p) {
                    rehash_subset.push(id);
                }
            }
        }
        if rehash_subset.is_empty() {
            return;
        }
        for ids in local_ids.iter_mut() {
            ids.clear();
        }
        for &g in rehash_subset.iter() {
            let (s, local) = mirror.map().locate(g as usize);
            local_ids[s].push(local as u32);
        }
        for (s, (shard, ids)) in shards.iter_mut().zip(local_ids.iter()).enumerate() {
            if !ids.is_empty() {
                shard.rehash_nodes(mirror.plane(s), ids, rng);
            }
        }
    }

    /// Epoch-cadence rebuild, staggered per shard: shard `s` rebuilds
    /// when `(epoch + 1 + s) % rebuild_every == 0`, so the per-epoch
    /// rebuild cost is spread across shards instead of spiking. The
    /// shard's mirror slice is fully re-synced first (Hogwild staleness
    /// bound). At `S = 1` the cadence and RNG consumption are exactly
    /// the unsharded selector's.
    ///
    /// `force_all` (the health-driven rebuild path) rebuilds every shard
    /// regardless of cadence, in shard order. With `force_all = false`
    /// this is bit-for-bit the fixed staggered schedule.
    pub fn maybe_rebuild_staggered(
        &mut self,
        weights: &Matrix,
        epoch: usize,
        rebuild_every: usize,
        force_all: bool,
        rng: &mut Pcg64,
    ) {
        let Self { mirror, shards, .. } = self;
        for (s, shard) in shards.iter_mut().enumerate() {
            if force_all || (epoch + 1 + s) % rebuild_every == 0 {
                mirror.sync_shard(weights, s);
                shard.rebuild(mirror.plane(s), rng);
                crate::obs::events::emit(
                    crate::obs::EventKind::ShardRebuild,
                    "shard",
                    s as u64,
                    if force_all { "forced" } else { "staggered" },
                );
            }
        }
    }
}

/// Immutable sharded table stack for serving: one [`FrozenLayerTables`]
/// per shard plus a stack-level global-id health tally (shared across
/// clones, like the single-stack one).
#[derive(Clone)]
pub struct ShardedFrozenTables {
    map: ShardMap,
    shards: Vec<FrozenLayerTables>,
    health: Arc<HealthTally>,
}

impl ShardedFrozenTables {
    pub fn freeze(live: &ShardedLayerTables) -> Self {
        ShardedFrozenTables {
            map: *live.map(),
            shards: live.shards.iter().map(FrozenLayerTables::freeze).collect(),
            health: Arc::new(HealthTally::new(live.n_nodes())),
        }
    }

    /// Delta re-freeze, shard-granular: every shard whose live tables have
    /// not mutated since `prev` was frozen (mutation stamp unchanged) is
    /// shared from `prev` outright; only shards with touched rows — or a
    /// rebuild, which also bumps the stamp — are re-frozen. Bucket-for-
    /// bucket identical to [`Self::freeze`] on `live`. The stack-level
    /// health tally is fresh, matching `freeze`.
    pub fn refreeze_delta(live: &ShardedLayerTables, prev: &ShardedFrozenTables) -> Self {
        debug_assert_eq!(prev.shard_count(), live.shard_count(), "refreeze across shard layouts");
        ShardedFrozenTables {
            map: *live.map(),
            shards: live
                .shards
                .iter()
                .zip(&prev.shards)
                .map(|(l, p)| FrozenLayerTables::refreeze_delta(l, p))
                .collect(),
            health: Arc::new(HealthTally::new(live.n_nodes())),
        }
    }

    /// Reassemble from per-shard frozen stacks (snapshot load), checking
    /// each shard's node count against the block layout for `n_nodes`.
    pub fn from_parts(shards: Vec<FrozenLayerTables>, n_nodes: usize) -> Result<Self, String> {
        if shards.is_empty() {
            return Err("sharded table stack needs at least one shard".into());
        }
        let map = ShardMap::new(n_nodes, shards.len());
        if map.shards() != shards.len() {
            return Err(format!(
                "{} shards cannot own {n_nodes} nodes (block layout caps at {})",
                shards.len(),
                map.shards()
            ));
        }
        for (s, shard) in shards.iter().enumerate() {
            if shard.n_nodes() != map.rows_in(s) {
                return Err(format!(
                    "shard {s} holds {} nodes, block layout says {}",
                    shard.n_nodes(),
                    map.rows_in(s)
                ));
            }
        }
        let health = Arc::new(HealthTally::new(n_nodes));
        Ok(ShardedFrozenTables { map, shards, health })
    }

    pub fn config(&self) -> LshConfig {
        self.shards[0].config()
    }

    pub fn n_nodes(&self) -> usize {
        self.map.n_rows()
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    pub fn shards(&self) -> &[FrozenLayerTables] {
        &self.shards
    }

    /// Stack-level (global-id) health counters.
    pub fn health_tally(&self) -> &HealthTally {
        &self.health
    }

    /// One health row per shard (frozen stacks never rebuild in place).
    pub fn health_rows(&self) -> Vec<TableHealth> {
        (0..self.shards.len())
            .map(|s| {
                let sizes: Vec<Vec<usize>> =
                    self.shards[s].tables().iter().map(|t| t.bucket_sizes()).collect();
                TableHealth::compute_subset(&sizes, 0, &self.health, self.map.range(s))
            })
            .collect()
    }

    /// Per-sample hashing cost: every shard hashes the query with its
    /// own family, so the costs add.
    pub fn hash_mults(&self) -> u64 {
        self.shards.iter().map(|t| t.hash_mults()).sum()
    }

    /// Probe + rank one prehashed sample across all shards (serving
    /// side). `rng` must be the fingerprint-derived one (the caller
    /// derives it from the *full* concatenated fingerprints — at `S = 1`
    /// that is exactly the unsharded derivation). Each shard keeps its
    /// own scratch; the per-shard empty-result fallback inside
    /// [`FrozenLayerTables`] applies per shard.
    pub(crate) fn probe_prehashed_sharded(
        &self,
        fps: &[u32],
        budget: usize,
        collect_factor: usize,
        scratches: &mut [FrozenQueryScratch],
        budget_split: &mut Vec<usize>,
        rng: &mut Pcg64,
        out: &mut Vec<u32>,
    ) {
        out.clear();
        let l = self.config().l;
        debug_assert_eq!(fps.len(), l * self.shards.len());
        debug_assert_eq!(scratches.len(), self.shards.len());
        split_budget(&self.map, budget, budget_split);
        for (s, shard) in self.shards.iter().enumerate() {
            let share = budget_split[s] * collect_factor.max(1);
            if share == 0 {
                continue;
            }
            let scratch = &mut scratches[s];
            let mut tmp = std::mem::take(&mut scratch.sub_out);
            shard.probe_prehashed(&fps[s * l..(s + 1) * l], share, scratch, rng, &mut tmp);
            let base = self.map.base(s) as u32;
            out.extend(tmp.iter().map(|&id| id + base));
            scratch.sub_out = tmp;
        }
    }
}

/// What the publish slot carries per hidden layer: either the classic
/// single frozen stack or a sharded one. Selection dispatches on this;
/// everything shape-related answers through the enum so the publish /
/// snapshot / engine plumbing never cares which it holds.
#[derive(Clone)]
pub enum LayerTableStack {
    Single(FrozenLayerTables),
    Sharded(ShardedFrozenTables),
}

impl LayerTableStack {
    pub fn n_nodes(&self) -> usize {
        match self {
            LayerTableStack::Single(t) => t.n_nodes(),
            LayerTableStack::Sharded(t) => t.n_nodes(),
        }
    }

    pub fn config(&self) -> LshConfig {
        match self {
            LayerTableStack::Single(t) => t.config(),
            LayerTableStack::Sharded(t) => t.config(),
        }
    }

    /// 1 for a single stack, `S` for a sharded one.
    pub fn shard_count(&self) -> usize {
        match self {
            LayerTableStack::Single(_) => 1,
            LayerTableStack::Sharded(t) => t.shard_count(),
        }
    }

    pub fn single(&self) -> Option<&FrozenLayerTables> {
        match self {
            LayerTableStack::Single(t) => Some(t),
            LayerTableStack::Sharded(_) => None,
        }
    }

    pub fn sharded(&self) -> Option<&ShardedFrozenTables> {
        match self {
            LayerTableStack::Single(_) => None,
            LayerTableStack::Sharded(t) => Some(t),
        }
    }

    /// Stack-level health counters (single: the stack's own tally).
    pub fn health_tally(&self) -> &HealthTally {
        match self {
            LayerTableStack::Single(t) => t.health_tally(),
            LayerTableStack::Sharded(t) => t.health_tally(),
        }
    }

    /// Health rows: one for a single stack, one per shard for a sharded
    /// one.
    pub fn health_rows(&self) -> Vec<TableHealth> {
        match self {
            LayerTableStack::Single(t) => vec![t.health_snapshot()],
            LayerTableStack::Sharded(t) => t.health_rows(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn weights(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        Matrix::from_fn(n, d, |_, _| rng.gaussian() * 0.3)
    }

    #[test]
    fn split_budget_is_proportional_and_exact() {
        let mut out = Vec::new();
        let map = ShardMap::new(100, 4);
        split_budget(&map, 10, &mut out);
        assert_eq!(out.iter().sum::<usize>(), 10);
        assert_eq!(out, vec![3, 3, 2, 2]);
        // Uneven shards: last shard owns fewer rows, gets no more than
        // its share plus the remainder round-robin.
        let map = ShardMap::new(10, 3); // blocks 4, 4, 2
        split_budget(&map, 5, &mut out);
        assert_eq!(out.iter().sum::<usize>(), 5);
        assert_eq!(out, vec![2, 2, 1], "floor shares over blocks 4,4,2");
        // S=1 is the identity (the parity-critical case).
        split_budget(&ShardMap::new(50, 1), 7, &mut out);
        assert_eq!(out, vec![7]);
        // Degenerate empty layer.
        split_budget(&ShardMap::new(0, 3), 4, &mut out);
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn single_shard_build_is_bitwise_the_unsharded_build() {
        let w = weights(90, 12, 5);
        let cfg = LshConfig { k: 5, l: 4, ..Default::default() };
        let mut rng_a = Pcg64::seeded(6);
        let mut rng_b = Pcg64::seeded(6);
        let unsharded = LayerTables::build(&w, cfg, &mut rng_a);
        let sharded = ShardedLayerTables::build(&w, cfg, 1, &mut rng_b);
        assert_eq!(sharded.shard_count(), 1);
        assert_eq!(sharded.shard(0).tables(), unsharded.tables());
        assert_eq!(sharded.shard(0).family().max_norm(), unsharded.family().max_norm());
        // The two RNG streams must be at the same position afterwards.
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }

    #[test]
    fn single_shard_maintenance_tracks_the_unsharded_stream() {
        let mut w = weights(60, 8, 15);
        let cfg = LshConfig { k: 4, l: 3, rehash_probability: 0.5, ..Default::default() };
        let mut rng_a = Pcg64::seeded(16);
        let mut rng_b = Pcg64::seeded(16);
        let mut unsharded = LayerTables::build(&w, cfg, &mut rng_a);
        let mut sharded = ShardedLayerTables::build(&w, cfg, 1, &mut rng_b);
        // A gradient step touches some rows; both paths draw the same
        // bernoulli subset and rehash the same nodes.
        for &r in &[3u32, 17, 42] {
            for v in w.row_mut(r as usize) {
                *v = -*v;
            }
        }
        let touched = [3u32, 17, 42];
        // Unsharded reference: the selector's literal maintenance step.
        let mut subset = Vec::new();
        for &id in &touched {
            if rng_a.bernoulli(cfg.rehash_probability) {
                subset.push(id);
            }
        }
        if !subset.is_empty() {
            unsharded.rehash_nodes(&w, &subset, &mut rng_a);
        }
        sharded.post_update(&w, &touched, &mut rng_b);
        assert_eq!(sharded.shard(0).tables(), unsharded.tables());
        // Epoch-end rebuild consumes the same stream.
        unsharded.rebuild(&w, &mut rng_a);
        sharded.maybe_rebuild_staggered(&w, 0, 1, false, &mut rng_b);
        assert_eq!(sharded.shard(0).tables(), unsharded.tables());
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }

    #[test]
    fn sharded_probe_merges_valid_distinct_global_ids() {
        let w = weights(90, 10, 25);
        let cfg = LshConfig { k: 4, l: 3, ..Default::default() };
        let mut rng = Pcg64::seeded(26);
        let mut st = ShardedLayerTables::build(&w, cfg, 3, &mut rng);
        assert_eq!(st.shard_count(), 3);
        let q: Vec<f32> = (0..10).map(|j| (j as f32 * 0.41).sin()).collect();
        let mut fps = vec![0u32; 3 * cfg.l];
        st.hash_batch_sharded(&q, 1, &mut fps);
        let mut out = Vec::new();
        st.probe_prehashed_sharded(&fps, 30, 1, &mut rng, &mut out);
        assert!(!out.is_empty());
        assert!(out.len() <= 30);
        assert!(out.iter().all(|&id| (id as usize) < 90));
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), out.len(), "merged ids must be distinct");
        // Every merged id's owner shard is the one whose range holds it.
        for &id in &out {
            let s = st.map().shard_of(id as usize);
            assert!(st.map().range(s).contains(&(id as usize)));
        }
    }

    #[test]
    fn shard_rebuild_cadence_is_staggered() {
        let w = weights(40, 6, 35);
        let cfg = LshConfig { k: 3, l: 2, ..Default::default() };
        let mut rng = Pcg64::seeded(36);
        let mut st = ShardedLayerTables::build(&w, cfg, 4, &mut rng);
        // rebuild_every = 4: each epoch rebuilds exactly one shard.
        for epoch in 0..4 {
            let before = st.rebuilds();
            st.maybe_rebuild_staggered(&w, epoch, 4, false, &mut rng);
            assert_eq!(st.rebuilds(), before + 1, "epoch {epoch}");
        }
        // After 4 epochs every shard has rebuilt exactly once.
        for s in 0..4 {
            assert_eq!(st.shard(s).rebuilds, 1, "shard {s}");
        }
    }

    #[test]
    fn freeze_and_from_parts_round_trip() {
        let w = weights(50, 8, 45);
        let cfg = LshConfig { k: 4, l: 3, ..Default::default() };
        let mut rng = Pcg64::seeded(46);
        let live = ShardedLayerTables::build(&w, cfg, 4, &mut rng);
        let frozen = ShardedFrozenTables::freeze(&live);
        assert_eq!(frozen.shard_count(), 4);
        assert_eq!(frozen.n_nodes(), 50);
        for s in 0..4 {
            assert_eq!(frozen.shards()[s].tables(), live.shard(s).tables());
        }
        let rebuilt =
            ShardedFrozenTables::from_parts(frozen.shards().to_vec(), 50).expect("valid parts");
        assert_eq!(rebuilt.map(), frozen.map());
        // Wrong node count must be rejected.
        assert!(ShardedFrozenTables::from_parts(frozen.shards().to_vec(), 49).is_err());
        assert!(ShardedFrozenTables::from_parts(Vec::new(), 50).is_err());
    }

    #[test]
    fn delta_refreeze_shares_untouched_shards() {
        let mut w = weights(80, 8, 75);
        let cfg = LshConfig { k: 4, l: 3, ..Default::default() };
        let mut rng = Pcg64::seeded(76);
        let mut st = ShardedLayerTables::build(&w, cfg, 4, &mut rng);
        let prev = ShardedFrozenTables::freeze(&st);
        // Touch rows owned by shard 1 only (blocks of 20: rows 20..40).
        for &r in &[21u32, 35] {
            for v in w.row_mut(r as usize) {
                *v = -*v;
            }
        }
        st.post_update(&w, &[21, 35], &mut rng);
        let next = ShardedFrozenTables::refreeze_delta(&st, &prev);
        for s in 0..4 {
            assert_eq!(next.shards()[s].tables(), st.shard(s).tables(), "shard {s} exactness");
            let shared = next.shards()[s].frozen_stamp() == prev.shards()[s].frozen_stamp();
            assert_eq!(shared, s != 1, "only the touched shard re-freezes (shard {s})");
        }
    }

    #[test]
    fn stack_enum_answers_shape_questions_for_both_variants() {
        let w = weights(30, 6, 55);
        let cfg = LshConfig { k: 3, l: 2, ..Default::default() };
        let mut rng = Pcg64::seeded(56);
        let single =
            LayerTableStack::Single(FrozenLayerTables::freeze(&LayerTables::build(&w, cfg, &mut rng)));
        let sharded = LayerTableStack::Sharded(ShardedFrozenTables::freeze(
            &ShardedLayerTables::build(&w, cfg, 3, &mut rng),
        ));
        assert_eq!(single.n_nodes(), 30);
        assert_eq!(sharded.n_nodes(), 30);
        assert_eq!(single.shard_count(), 1);
        assert_eq!(sharded.shard_count(), 3);
        assert!(single.single().is_some() && single.sharded().is_none());
        assert!(sharded.sharded().is_some() && sharded.single().is_none());
        assert_eq!(single.health_rows().len(), 1);
        assert_eq!(sharded.health_rows().len(), 3);
        assert_eq!(sharded.health_rows().iter().map(|h| h.nodes).sum::<usize>(), 30);
    }

    #[test]
    fn per_shard_health_rows_partition_the_stack_tally() {
        let w = weights(20, 5, 65);
        let cfg = LshConfig { k: 3, l: 2, ..Default::default() };
        let mut rng = Pcg64::seeded(66);
        let st = ShardedLayerTables::build(&w, cfg, 2, &mut rng);
        st.health_tally().note_batch(&[vec![0, 1, 12], vec![12, 19]]);
        let rows = st.health_rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].active_nodes, 2);
        assert_eq!(rows[1].active_nodes, 2);
        assert_eq!(rows[0].selections + rows[1].selections, 5);
        assert_eq!(rows[1].max_node_activations, 2, "node 12 selected twice");
    }
}
