//! LSH family abstraction: a family produces K-bit fingerprints, one per
//! table, for *data* vectors (neuron weights) and *query* vectors (layer
//! inputs). The two roles are distinct because MIPS requires an asymmetric
//! transform (Shrivastava & Li, NIPS 2014 / UAI 2015): data and query pass
//! through different maps before the symmetric hash is applied.

/// A (K, L) locality-sensitive hash family for inner-product search.
pub trait LshFamily {
    /// Number of bits per fingerprint (K).
    fn k(&self) -> usize;
    /// Number of tables (L).
    fn l(&self) -> usize;
    /// Input dimensionality the family was built for.
    fn dim(&self) -> usize;

    /// Fingerprints for a *data* vector (one per table, `out.len() == L`).
    fn hash_data(&self, x: &[f32], out: &mut [u32]);

    /// Fingerprints for a *query* vector (one per table).
    fn hash_query(&self, q: &[f32], out: &mut [u32]);

    /// Convenience allocating wrappers.
    fn data_fingerprints(&self, x: &[f32]) -> Vec<u32> {
        let mut out = vec![0u32; self.l()];
        self.hash_data(x, &mut out);
        out
    }

    fn query_fingerprints(&self, q: &[f32]) -> Vec<u32> {
        let mut out = vec![0u32; self.l()];
        self.hash_query(q, &mut out);
        out
    }
}
