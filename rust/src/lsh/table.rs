//! A single LSH hash table: 2^K buckets holding *node ids* (pointers to
//! neurons, never the weights themselves — §5.4 of the paper). Insertion is
//! O(1) (push); deletion is O(b) via swap-remove where b is bucket size;
//! crowded buckets can be sub-sampled at query time.
//!
//! Storage is copy-on-write: each bucket and the per-node fingerprint
//! array sit behind `Arc`s, mutated through `Arc::make_mut`. While a
//! table is uniquely owned (training steady state) `make_mut` is a
//! refcount check and mutation stays in place — the hot path pays one
//! predictable branch. The payoff is that *cloning* a table (what a
//! publish-time freeze does) degenerates to Arc bumps: the frozen epoch
//! shares every bucket with the live table, and subsequent live updates
//! deep-copy only the buckets they actually move ids between. That is
//! what makes epoch publication O(touched) on the table side.

use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Bucket occupancy beyond which a bucket is considered "crowded" and is
/// reservoir-sub-sampled at query time instead of returned whole
/// (paper §5.4: "crowded buckets are not very informative and can be
/// safely ignored or sub-sampled").
pub const DEFAULT_CROWDED_LIMIT: usize = 128;

#[derive(Clone, Debug, PartialEq)]
pub struct HashTable {
    k: usize,
    /// Dense array of 2^K buckets (K ≤ 16 keeps this small; for K up to 32
    /// a sparse map would be needed, but the paper uses K=6). Each bucket
    /// is individually Arc'd so frozen clones share unmodified buckets.
    buckets: Vec<Arc<Vec<u32>>>,
    /// Current fingerprint of each node (`u32::MAX` = absent) — makes
    /// delete O(b) without scanning all buckets. Arc'd as one block: it is
    /// the O(capacity) part of the table, shared wholesale with frozen
    /// clones and copied lazily on the first post-freeze mutation.
    node_fp: Arc<Vec<u32>>,
    len: usize,
}

impl HashTable {
    /// `capacity` = number of node ids that will be stored (node ids must
    /// be `< capacity`).
    pub fn new(k: usize, capacity: usize) -> Self {
        assert!(k <= 16, "dense bucket array supports K <= 16 (paper uses 6)");
        HashTable {
            k,
            buckets: vec![Arc::new(Vec::new()); 1 << k],
            node_fp: Arc::new(vec![u32::MAX; capacity]),
            len: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    fn mask(&self, fp: u32) -> usize {
        (fp as usize) & ((1usize << self.k) - 1)
    }

    /// Insert node `id` under fingerprint `fp`. O(1) (amortized: the
    /// first mutation after a freeze copies the shared bucket/fp block).
    pub fn insert(&mut self, id: u32, fp: u32) {
        debug_assert_eq!(self.node_fp[id as usize], u32::MAX, "node already present");
        let b = self.mask(fp);
        Arc::make_mut(&mut self.buckets[b]).push(id);
        Arc::make_mut(&mut self.node_fp)[id as usize] = fp;
        self.len += 1;
    }

    /// Remove node `id` (must be present). O(bucket size) via swap-remove.
    pub fn remove(&mut self, id: u32) {
        let fp = self.node_fp[id as usize];
        debug_assert_ne!(fp, u32::MAX, "node not present");
        let b = self.mask(fp);
        let bucket = Arc::make_mut(&mut self.buckets[b]);
        let pos = bucket.iter().position(|&x| x == id).expect("node missing from bucket");
        bucket.swap_remove(pos);
        Arc::make_mut(&mut self.node_fp)[id as usize] = u32::MAX;
        self.len -= 1;
    }

    /// Re-locate node `id` under a new fingerprint; no-op if the bucket is
    /// unchanged (the common case — small weight updates rarely flip bits).
    pub fn update(&mut self, id: u32, new_fp: u32) {
        let old = self.node_fp[id as usize];
        if old != u32::MAX && self.mask(old) == self.mask(new_fp) {
            Arc::make_mut(&mut self.node_fp)[id as usize] = new_fp;
            return;
        }
        if old != u32::MAX {
            self.remove(id);
        }
        self.insert(id, new_fp);
    }

    pub fn contains(&self, id: u32) -> bool {
        self.node_fp[id as usize] != u32::MAX
    }

    pub fn fingerprint_of(&self, id: u32) -> Option<u32> {
        match self.node_fp[id as usize] {
            u32::MAX => None,
            fp => Some(fp),
        }
    }

    /// Bucket contents for a fingerprint.
    pub fn bucket(&self, fp: u32) -> &[u32] {
        &self.buckets[self.mask(fp)]
    }

    /// Probe a bucket into `out`, sub-sampling crowded buckets with the
    /// caller's RNG (reservoir sample of `crowded_limit` ids).
    pub fn probe_into(
        &self,
        fp: u32,
        crowded_limit: usize,
        rng: &mut Pcg64,
        out: &mut Vec<u32>,
    ) {
        let bucket = self.bucket(fp);
        if bucket.len() <= crowded_limit {
            out.extend_from_slice(bucket);
        } else {
            // Reservoir sample without replacement.
            let mut reservoir: Vec<u32> = bucket[..crowded_limit].to_vec();
            for (i, &id) in bucket.iter().enumerate().skip(crowded_limit) {
                let j = rng.below(i as u32 + 1) as usize;
                if j < crowded_limit {
                    reservoir[j] = id;
                }
            }
            out.extend_from_slice(&reservoir);
        }
    }

    /// Hint the hardware prefetcher at this fingerprint's bucket id array.
    /// The probe loop calls this for every table's next address *before*
    /// scanning any of them ([`probe_into`](Self::probe_into) walks the
    /// bucket afterwards on warm lines). A pure hint — never changes
    /// results, only latency.
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    #[inline]
    pub fn prefetch_bucket(&self, fp: u32) {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        let bucket: &[u32] = &self.buckets[self.mask(fp)];
        if !bucket.is_empty() {
            // SAFETY: prefetch is a hint; any address is permitted.
            unsafe { _mm_prefetch::<_MM_HINT_T0>(bucket.as_ptr() as *const i8) };
        }
    }

    /// Occupancy histogram (for diagnostics / ablation benches).
    pub fn bucket_sizes(&self) -> Vec<usize> {
        self.buckets.iter().map(|b| b.len()).collect()
    }

    /// Read-only view of the bucket arrays (frozen-snapshot serialization
    /// and the lock-free serving probes read these directly). Each entry
    /// deref-coerces to `&[u32]`.
    pub fn buckets(&self) -> &[Arc<Vec<u32>>] {
        &self.buckets
    }

    /// How many of the 2^K buckets are *the same allocation* as the
    /// matching bucket of `other` — the sharing a freeze-clone keeps, and
    /// what "re-freeze only buckets whose member rows moved" measures.
    pub fn shared_buckets_with(&self, other: &HashTable) -> usize {
        self.buckets.iter().zip(&other.buckets).filter(|(a, b)| Arc::ptr_eq(a, b)).count()
    }

    /// Whether the per-node fingerprint block is shared with `other`.
    pub fn shares_fingerprints_with(&self, other: &HashTable) -> bool {
        Arc::ptr_eq(&self.node_fp, &other.node_fp)
    }

    /// Per-node stored fingerprint, `u32::MAX` = not present. Length is the
    /// table capacity.
    pub fn node_fingerprints(&self) -> &[u32] {
        &self.node_fp
    }

    /// Reconstruct a table from serialized parts, preserving the exact
    /// in-bucket ordering (which matters: probe collection order breaks
    /// ranking ties). Validates the bucket/fingerprint cross-invariants so
    /// a corrupt snapshot fails loudly instead of probing garbage.
    pub fn from_parts(
        k: usize,
        node_fp: Vec<u32>,
        buckets: Vec<Vec<u32>>,
    ) -> Result<Self, String> {
        if k > 16 {
            return Err(format!("hash table K={k} out of range (max 16)"));
        }
        if buckets.len() != 1 << k {
            return Err(format!("expected {} buckets for K={k}, got {}", 1 << k, buckets.len()));
        }
        let mask = |fp: u32| (fp as usize) & ((1usize << k) - 1);
        let mut len = 0usize;
        let mut seen = vec![false; node_fp.len()];
        for (b, bucket) in buckets.iter().enumerate() {
            for &id in bucket {
                let fp = *node_fp
                    .get(id as usize)
                    .ok_or_else(|| format!("bucket id {id} out of capacity"))?;
                if fp == u32::MAX {
                    return Err(format!("node {id} in a bucket but marked absent"));
                }
                if mask(fp) != b {
                    return Err(format!("node {id} fingerprint maps to bucket {}, stored in {b}", mask(fp)));
                }
                if seen[id as usize] {
                    return Err(format!("node {id} appears in two buckets"));
                }
                seen[id as usize] = true;
                len += 1;
            }
        }
        let present = node_fp.iter().filter(|&&fp| fp != u32::MAX).count();
        if present != len {
            return Err(format!("{present} fingerprints but {len} bucket entries"));
        }
        Ok(HashTable {
            k,
            buckets: buckets.into_iter().map(Arc::new).collect(),
            node_fp: Arc::new(node_fp),
            len,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_then_probe() {
        let mut t = HashTable::new(4, 10);
        t.insert(3, 0b1010);
        t.insert(7, 0b1010);
        t.insert(5, 0b0001);
        assert_eq!(t.len(), 3);
        assert_eq!(t.bucket(0b1010), &[3, 7]);
        assert_eq!(t.bucket(0b0001), &[5]);
        assert!(t.bucket(0b1111).is_empty());
    }

    #[test]
    fn remove_swaps_out() {
        let mut t = HashTable::new(4, 10);
        for id in 0..4 {
            t.insert(id, 0b0011);
        }
        t.remove(1);
        assert_eq!(t.len(), 3);
        assert!(!t.contains(1));
        let mut b = t.bucket(0b0011).to_vec();
        b.sort_unstable();
        assert_eq!(b, vec![0, 2, 3]);
    }

    #[test]
    fn update_moves_between_buckets() {
        let mut t = HashTable::new(4, 4);
        t.insert(0, 0b0000);
        t.update(0, 0b1111);
        assert!(t.bucket(0b0000).is_empty());
        assert_eq!(t.bucket(0b1111), &[0]);
        assert_eq!(t.fingerprint_of(0), Some(0b1111));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn update_same_bucket_is_noop_move() {
        let mut t = HashTable::new(4, 4);
        t.insert(0, 0b0101);
        t.insert(1, 0b0101);
        t.update(0, 0b0101);
        assert_eq!(t.bucket(0b0101), &[0, 1], "order preserved on same-bucket update");
    }

    #[test]
    fn update_inserts_missing_node() {
        let mut t = HashTable::new(4, 4);
        t.update(2, 0b0010);
        assert!(t.contains(2));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn crowded_bucket_subsampled() {
        let mut t = HashTable::new(2, 1000);
        for id in 0..500 {
            t.insert(id, 0b01);
        }
        let mut rng = Pcg64::seeded(1);
        let mut out = Vec::new();
        t.probe_into(0b01, 32, &mut rng, &mut out);
        assert_eq!(out.len(), 32);
        let mut s = out.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 32, "sample must be without replacement");
        assert!(s.iter().all(|&id| id < 500));
    }

    #[test]
    fn small_bucket_returned_whole() {
        let mut t = HashTable::new(2, 10);
        t.insert(1, 0);
        t.insert(2, 0);
        let mut rng = Pcg64::seeded(1);
        let mut out = Vec::new();
        t.probe_into(0, 32, &mut rng, &mut out);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn fingerprint_mask_ignores_high_bits() {
        let mut t = HashTable::new(4, 4);
        t.insert(0, 0xFFFF_FFF0); // low 4 bits = 0
        assert_eq!(t.bucket(0x0000_0000), &[0]);
    }

    #[test]
    fn from_parts_roundtrip_preserves_order() {
        let mut t = HashTable::new(4, 16);
        for id in 0..12 {
            t.insert(id, (id * 7) % 16);
        }
        t.remove(5);
        t.update(3, 0b1111); // force some swap-remove reordering
        let back = HashTable::from_parts(
            t.k(),
            t.node_fingerprints().to_vec(),
            t.buckets().iter().map(|b| b.as_ref().clone()).collect(),
        )
        .unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn from_parts_rejects_inconsistencies() {
        let mut t = HashTable::new(2, 4);
        t.insert(0, 0b01);
        let mut bad_buckets: Vec<Vec<u32>> =
            t.buckets().iter().map(|b| b.as_ref().clone()).collect();
        bad_buckets[0].push(0); // node 0 duplicated into the wrong bucket
        assert!(HashTable::from_parts(2, t.node_fingerprints().to_vec(), bad_buckets).is_err());
        assert!(HashTable::from_parts(2, t.node_fingerprints().to_vec(), vec![Vec::new(); 3])
            .is_err());
    }

    #[test]
    fn clone_shares_storage_until_mutation() {
        let mut t = HashTable::new(4, 32);
        for id in 0..20 {
            t.insert(id, (id * 5) % 16);
        }
        let frozen = t.clone();
        assert_eq!(t.shared_buckets_with(&frozen), 16, "a fresh clone shares every bucket");
        assert!(t.shares_fingerprints_with(&frozen));
        // Move one node between two buckets: exactly those two buckets
        // (plus the fingerprint block) unshare; the clone is untouched.
        t.update(4, 0b0001); // fp 4 -> fp 1: bucket 4 drains into bucket 1
        assert_eq!(t.shared_buckets_with(&frozen), 14, "only the two moved buckets copied");
        assert!(!t.shares_fingerprints_with(&frozen));
        assert!(t.bucket(4).is_empty());
        assert_eq!(frozen.bucket(4), &[4u32][..], "frozen clone immune to live mutation");
        // Same-bucket fp refresh copies only the fingerprint block.
        let f2 = t.clone();
        let shared_before = t.shared_buckets_with(&f2);
        let fp = t.fingerprint_of(7).unwrap();
        t.update(7, fp); // same bucket
        assert_eq!(t.shared_buckets_with(&f2), shared_before, "no bucket copied");
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)] // the guard is a debug_assert, absent in release
    fn double_insert_panics_in_debug() {
        let mut t = HashTable::new(4, 4);
        t.insert(0, 1);
        t.insert(0, 2);
    }
}
