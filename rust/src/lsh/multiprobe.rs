//! Multi-probe LSH (Lv et al., VLDB 2007) for binary fingerprints.
//!
//! Instead of building many tables, probe several "close-by" buckets in
//! each table by perturbing the query fingerprint. For K-bit SRP
//! fingerprints the natural perturbation is flipping bits; nearer buckets
//! (fewer flipped bits) are probed first. The paper (§5.4): "Multi-probe
//! with binary hash function is quite straightforward. We just have to
//! randomly flip few bits of the K-bit hash to generate more addresses."

use crate::util::bitpack::flip_bit;

/// Generate the probe sequence for a K-bit fingerprint: the query bucket
/// itself, then all Hamming-distance-1 buckets, then distance-2, ... until
/// `max_probes` addresses have been produced. Deterministic and in
/// bit-order within a distance class.
pub fn probe_sequence(fp: u32, k: usize, max_probes: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(max_probes.min(1 << k));
    probe_sequence_into(fp, k, max_probes, &mut out);
    out
}

/// Allocation-free variant of [`probe_sequence`]: appends into a caller
/// buffer (cleared first), so the batched query path can reuse one
/// allocation per table across the whole minibatch.
pub fn probe_sequence_into(fp: u32, k: usize, max_probes: usize, out: &mut Vec<u32>) {
    out.clear();
    out.push(fp);
    if out.len() >= max_probes {
        return;
    }
    // Distance 1.
    for i in 0..k {
        out.push(flip_bit(fp, k, i));
        if out.len() >= max_probes {
            return;
        }
    }
    // Distance 2.
    for i in 0..k {
        for j in i + 1..k {
            out.push(flip_bit(flip_bit(fp, k, i), k, j));
            if out.len() >= max_probes {
                return;
            }
        }
    }
    // Distance 3 — enough for any practical probe budget at K=6..16.
    for i in 0..k {
        for j in i + 1..k {
            for m in j + 1..k {
                out.push(flip_bit(flip_bit(flip_bit(fp, k, i), k, j), k, m));
                if out.len() >= max_probes {
                    return;
                }
            }
        }
    }
}

/// An iterator-style probe generator that owns its state; avoids allocating
/// when the caller early-exits after finding enough nodes (§6.2.1: "We stop
/// early if we find that we have sampled enough nodes even before
/// exhausting all buckets").
pub struct ProbeGen {
    seq: Vec<u32>,
    pos: usize,
}

impl ProbeGen {
    pub fn new(fp: u32, k: usize, max_probes: usize) -> Self {
        ProbeGen { seq: probe_sequence(fp, k, max_probes), pos: 0 }
    }

    /// Re-arm for a new fingerprint, reusing the internal buffer (the
    /// batched selection path resets L generators per sample instead of
    /// allocating them).
    pub fn reset(&mut self, fp: u32, k: usize, max_probes: usize) {
        probe_sequence_into(fp, k, max_probes, &mut self.seq);
        self.pos = 0;
    }

    /// An empty generator (yields nothing until `reset`).
    pub fn idle() -> Self {
        ProbeGen { seq: Vec::new(), pos: 0 }
    }
}

impl Iterator for ProbeGen {
    type Item = u32;
    fn next(&mut self) -> Option<u32> {
        let v = self.seq.get(self.pos).copied();
        self.pos += 1;
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bitpack::hamming;

    #[test]
    fn first_probe_is_query_bucket() {
        assert_eq!(probe_sequence(0b1010, 4, 5)[0], 0b1010);
    }

    #[test]
    fn probes_are_distinct() {
        let seq = probe_sequence(0b101010, 6, 42);
        let mut s = seq.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), seq.len());
    }

    #[test]
    fn probes_ordered_by_hamming_distance() {
        let fp = 0b110100;
        let seq = probe_sequence(fp, 6, 42);
        let dists: Vec<u32> = seq.iter().map(|&p| hamming(fp, p)).collect();
        for w in dists.windows(2) {
            assert!(w[0] <= w[1], "distances must be non-decreasing: {dists:?}");
        }
        assert_eq!(dists[0], 0);
        assert_eq!(dists[1], 1);
    }

    #[test]
    fn respects_max_probes() {
        assert_eq!(probe_sequence(0, 6, 10).len(), 10);
        assert_eq!(probe_sequence(0, 6, 1).len(), 1);
    }

    #[test]
    fn full_enumeration_at_small_k() {
        // K=3: 1 + 3 + 3 + 1 = 8 possible buckets.
        let seq = probe_sequence(0b000, 3, 64);
        assert_eq!(seq.len(), 8);
        let mut s = seq;
        s.sort_unstable();
        assert_eq!(s, (0..8).collect::<Vec<u32>>());
    }

    #[test]
    fn generator_matches_sequence() {
        let seq = probe_sequence(0b0110, 4, 9);
        let gen: Vec<u32> = ProbeGen::new(0b0110, 4, 9).collect();
        assert_eq!(seq, gen);
    }

    #[test]
    fn reset_reuses_generator() {
        let mut g = ProbeGen::idle();
        assert_eq!(g.next(), None);
        g.reset(0b0110, 4, 9);
        let got: Vec<u32> = (&mut g).collect();
        assert_eq!(got, probe_sequence(0b0110, 4, 9));
        g.reset(0b0001, 4, 3);
        let got: Vec<u32> = g.collect();
        assert_eq!(got, probe_sequence(0b0001, 4, 3));
    }
}
