//! Unified telemetry: stage tracing, table health, and one exporter.
//!
//! This is the observability layer the whole stack shares. Three parts:
//!
//! * **Stage timing** (this module + [`trace`]): every unit of work on
//!   the request path — queue wait, epoch pin, densify, hash,
//!   probe/rank, fused gather, output layer, backprop — runs inside a
//!   scoped timer that feeds a global per-stage [`LatencyHistogram`].
//!   `--trace-sample N` additionally captures every Nth micro-batch's
//!   full span tree.
//! * **Table health** ([`health`]): per-node activation counters,
//!   bucket-occupancy skew, rebuild age and a sampled selection-recall
//!   estimate, owned by `LayerTables`/`FrozenLayerTables` and surfaced
//!   through `TableView::health`.
//! * **Exporter** ([`export`]): a process-wide [`MetricsRegistry`] of
//!   reader closures rendering Prometheus text and JSON.
//! * **Drift observatory** ([`series`], [`events`], [`drift`],
//!   [`http`]): ring time-series over the registry with a background
//!   sampler, a bounded structured event journal, detectors that turn
//!   health decay into `DriftAlert`s and health-driven rebuilds, and a
//!   std-only HTTP listener serving `/metrics`, `/metrics.json`,
//!   `/events` and `/health`.
//!
//! Design contract, pinned by `tests/telemetry.rs` and
//! `tests/observatory.rs`: telemetry must not change model output.
//! Nothing here draws from an RNG, and no forward or backward code path
//! branches on a counter value — recording is relaxed atomics, reading
//! is pure. The master switch [`set_enabled`] exists for overhead
//! measurement, not correctness. (`RebuildPolicy::HealthDriven` is the
//! one deliberate exception: it changes *when* tables rebuild; the
//! default `Fixed` policy is bit-for-bit the pre-observatory cadence.)

pub mod drift;
pub mod events;
pub mod export;
pub mod health;
pub mod http;
pub mod series;
pub mod trace;

pub use drift::{DriftAlert, DriftConfig, HealthDriftDetector, RebuildPolicy};
pub use events::EventKind;
pub use export::{global, MetricKind, MetricsRegistry, MetricsSnapshot};
pub use health::{recall_due, recall_probe, set_recall_every, HealthTally, TableHealth};
pub use trace::{
    set_trace_every, trace_active, trace_begin, trace_due, trace_end, Stage, Trace, TraceEvent,
    N_STAGES, STAGES,
};

use crate::serve::stats::{LatencyHistogram, LatencySnapshot};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Master switch. On by default; `--telemetry off` exists so the CI
/// overhead pin can measure the instrumented-vs-not delta.
static ENABLED: AtomicBool = AtomicBool::new(true);

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Microseconds since the process-wide observability epoch (first call
/// to anything that needs a timestamp). Event and series timestamps
/// share this clock so they correlate.
pub fn uptime_micros() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Global per-stage latency histograms. One fixed array — all pools,
/// trainers and shards accumulate into the same stage buckets.
pub struct StageStats {
    hists: [LatencyHistogram; N_STAGES],
}

impl StageStats {
    fn new() -> Self {
        StageStats { hists: std::array::from_fn(|_| LatencyHistogram::new()) }
    }

    #[inline]
    pub fn record(&self, stage: Stage, micros: u64) {
        self.hists[stage.index()].record(micros);
    }

    pub fn snapshot(&self, stage: Stage) -> LatencySnapshot {
        self.hists[stage.index()].snapshot()
    }

    /// Snapshot every stage in pipeline order.
    pub fn all(&self) -> Vec<(&'static str, LatencySnapshot)> {
        STAGES.iter().map(|&s| (s.name(), self.snapshot(s))).collect()
    }
}

/// Process-wide cumulative counters (never reset — the monotone series
/// CI asserts on, unlike per-pool counters which die with their pool).
pub struct Totals {
    /// Micro-batches that went through stage timing.
    pub batches: AtomicU64,
    /// Span tokens closed (stage recordings).
    pub spans: AtomicU64,
    /// Full span trees emitted by `--trace-sample`.
    pub traces: AtomicU64,
}

/// The global stage histograms; first call registers them (and the
/// totals) into the global metrics registry.
pub fn stages() -> &'static StageStats {
    static S: OnceLock<StageStats> = OnceLock::new();
    static REG: OnceLock<()> = OnceLock::new();
    let s: &'static StageStats = S.get_or_init(StageStats::new);
    REG.get_or_init(|| {
        for st in STAGES {
            let name = format!("hashdl_stage_{}_micros", st.name());
            export::global().register_histogram(&name, move || s.snapshot(st));
        }
        let t = totals();
        export::global()
            .register_counter("hashdl_obs_batches_total", || {
                totals().batches.load(Ordering::Relaxed) as f64
            });
        export::global()
            .register_counter("hashdl_obs_spans_total", || {
                t.spans.load(Ordering::Relaxed) as f64
            });
        export::global()
            .register_counter("hashdl_obs_traces_total", || {
                totals().traces.load(Ordering::Relaxed) as f64
            });
    });
    s
}

pub fn totals() -> &'static Totals {
    static T: OnceLock<Totals> = OnceLock::new();
    T.get_or_init(|| Totals {
        batches: AtomicU64::new(0),
        spans: AtomicU64::new(0),
        traces: AtomicU64::new(0),
    })
}

/// An open stage span. Obtain via [`begin`], close via [`end`] (or
/// [`end_at`] when the duration was measured externally).
#[must_use]
pub struct SpanToken {
    stage: Stage,
    start: Instant,
}

/// Open a span for `stage`. Returns `None` when telemetry is disabled —
/// the whole begin/end pair is then two relaxed loads and no clock
/// reads.
#[inline]
pub fn begin(stage: Stage) -> Option<SpanToken> {
    if !enabled() {
        return None;
    }
    trace::note_open(stage);
    Some(SpanToken { stage, start: Instant::now() })
}

/// Close a span: records into the global stage histogram and the active
/// trace (if any).
#[inline]
pub fn end(token: Option<SpanToken>) {
    if let Some(t) = token {
        let dur = t.start.elapsed().as_micros() as u64;
        stages().record(t.stage, dur);
        totals().spans.fetch_add(1, Ordering::Relaxed);
        trace::note_close(t.stage, t.start, dur);
    }
}

/// Record an externally-measured duration for `stage` (e.g. queue wait,
/// whose start predates the worker picking the request up). No-op when
/// telemetry is disabled.
#[inline]
pub fn record_stage(stage: Stage, start: Instant, dur_micros: u64) {
    if !enabled() {
        return;
    }
    stages().record(stage, dur_micros);
    totals().spans.fetch_add(1, Ordering::Relaxed);
    trace::note_close(stage, start, dur_micros);
}

/// Count one micro-batch through the instrumented path.
#[inline]
pub fn note_batch() {
    if enabled() {
        totals().batches.fetch_add(1, Ordering::Relaxed);
    }
}

/// Count one emitted trace.
pub fn note_trace() {
    totals().traces.fetch_add(1, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    // The enable flag is process-global; tests that flip it live in
    // tests/telemetry.rs (a separate, internally-serialised binary).
    // Here only additive behaviour is exercised.

    #[test]
    fn span_records_into_stage_histogram() {
        let before = stages().snapshot(Stage::Densify).count();
        let tok = begin(Stage::Densify);
        end(tok);
        let after = stages().snapshot(Stage::Densify).count();
        assert!(after >= before + 1);
    }

    #[test]
    fn record_stage_feeds_externally_timed_spans() {
        let before = stages().snapshot(Stage::Queue).sum_micros;
        record_stage(Stage::Queue, Instant::now(), 123);
        let after = stages().snapshot(Stage::Queue).sum_micros;
        assert!(after >= before + 123);
    }

    #[test]
    fn stage_registration_reaches_global_registry() {
        stages();
        let names = export::global().snapshot().names();
        for st in STAGES {
            let want = format!("hashdl_stage_{}_micros", st.name());
            assert!(names.contains(&want), "missing {want}");
        }
        assert!(names.contains(&"hashdl_obs_batches_total".to_string()));
    }

    #[test]
    fn trace_captures_nested_spans_in_order() {
        trace_begin(42);
        let outer = begin(Stage::ProbeRank);
        let inner = begin(Stage::Gather);
        end(inner);
        end(outer);
        let tr = trace_end().expect("trace was active");
        assert_eq!(tr.id, 42);
        // Only spans from this thread's trace window, nested correctly.
        assert_eq!(tr.events.len(), 2);
        let probe = tr.events.iter().find(|e| e.stage == Stage::ProbeRank).unwrap();
        let gather = tr.events.iter().find(|e| e.stage == Stage::Gather).unwrap();
        assert_eq!(probe.depth, 0);
        assert_eq!(gather.depth, 1, "inner span must nest under outer");
        assert!(gather.start_micros >= probe.start_micros, "events sorted by start");
        assert_eq!(tr.events[0].stage, Stage::ProbeRank);
    }

    #[test]
    fn trace_end_without_begin_is_none() {
        assert!(trace_end().is_none());
        assert!(!trace_active());
    }
}
