//! One metrics registry, two output formats.
//!
//! Everything the stack measures — stage histograms, pool counters,
//! router stats, table health — registers a named *reader closure*
//! here; a snapshot walks the readers and renders Prometheus text
//! exposition or JSON. The registry holds closures, not values, so the
//! hot paths keep writing their own relaxed atomics and pay nothing for
//! being exported; the `Mutex` is touched only on register/snapshot.

use crate::serve::stats::LatencySnapshot;
use crate::util::json::{JsonArray, JsonObject};
use std::sync::{Mutex, OnceLock};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
}

impl MetricKind {
    fn prom(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
        }
    }
}

type ReadScalar = Box<dyn Fn() -> f64 + Send + Sync>;
type ReadHist = Box<dyn Fn() -> LatencySnapshot + Send + Sync>;

/// Named counters/gauges/histograms, read lazily at snapshot time.
/// Re-registering a name replaces the reader (pools come and go in
/// benches; the latest owner of a name wins).
#[derive(Default)]
pub struct MetricsRegistry {
    scalars: Mutex<Vec<(String, MetricKind, ReadScalar)>>,
    hists: Mutex<Vec<(String, ReadHist)>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register_scalar(
        &self,
        name: &str,
        kind: MetricKind,
        read: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        let mut v = self.scalars.lock().unwrap();
        if let Some(slot) = v.iter_mut().find(|(n, _, _)| n == name) {
            slot.1 = kind;
            slot.2 = Box::new(read);
        } else {
            v.push((name.to_string(), kind, Box::new(read)));
        }
    }

    pub fn register_counter(&self, name: &str, read: impl Fn() -> f64 + Send + Sync + 'static) {
        self.register_scalar(name, MetricKind::Counter, read);
    }

    pub fn register_gauge(&self, name: &str, read: impl Fn() -> f64 + Send + Sync + 'static) {
        self.register_scalar(name, MetricKind::Gauge, read);
    }

    pub fn register_histogram(
        &self,
        name: &str,
        read: impl Fn() -> LatencySnapshot + Send + Sync + 'static,
    ) {
        let mut v = self.hists.lock().unwrap();
        if let Some(slot) = v.iter_mut().find(|(n, _)| n == name) {
            slot.1 = Box::new(read);
        } else {
            v.push((name.to_string(), Box::new(read)));
        }
    }

    /// Read every registered metric once.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let scalars = self
            .scalars
            .lock()
            .unwrap()
            .iter()
            .map(|(n, k, f)| (n.clone(), *k, f()))
            .collect();
        let hists =
            self.hists.lock().unwrap().iter().map(|(n, f)| (n.clone(), f())).collect();
        MetricsSnapshot { scalars, hists }
    }
}

/// A point-in-time reading of every registered metric.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub scalars: Vec<(String, MetricKind, f64)>,
    pub hists: Vec<(String, LatencySnapshot)>,
}

impl MetricsSnapshot {
    /// Prometheus text exposition format. Histograms render cumulative
    /// `_bucket{le=...}` series (only the occupied bounds plus `+Inf`),
    /// `_sum` and `_count`.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, kind, v) in &self.scalars {
            out.push_str(&format!("# TYPE {name} {}\n", kind.prom()));
            if *v == v.trunc() && v.abs() < 9.0e15 {
                out.push_str(&format!("{name} {}\n", *v as i64));
            } else {
                out.push_str(&format!("{name} {v}\n"));
            }
        }
        for (name, snap) in &self.hists {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cum = 0u64;
            for (i, &c) in snap.counts.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                cum += c;
                out.push_str(&format!(
                    "{name}_bucket{{le=\"{}\"}} {cum}\n",
                    LatencySnapshot::bucket_upper(i)
                ));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", snap.count()));
            out.push_str(&format!("{name}_sum {}\n", snap.sum_micros));
            out.push_str(&format!("{name}_count {}\n", snap.count()));
        }
        out
    }

    /// JSON rendering: scalars verbatim, histograms summarised
    /// (count/sum/mean/p50/p99).
    pub fn to_json(&self) -> String {
        let mut counters = JsonObject::new();
        let mut gauges = JsonObject::new();
        for (name, kind, v) in &self.scalars {
            match kind {
                MetricKind::Counter => counters.f64(name, *v),
                MetricKind::Gauge => gauges.f64(name, *v),
            };
        }
        let mut hists = JsonObject::new();
        for (name, snap) in &self.hists {
            let mut h = JsonObject::new();
            h.u64("count", snap.count())
                .u64("sum_micros", snap.sum_micros)
                .fixed("mean_micros", snap.mean_micros(), 1)
                .u64("p50_micros", snap.percentile_micros(50.0))
                .u64("p99_micros", snap.percentile_micros(99.0));
            hists.raw(name, &h.finish());
        }
        let mut o = JsonObject::new();
        o.raw("counters", &counters.finish())
            .raw("gauges", &gauges.finish())
            .raw("histograms", &hists.finish());
        o.finish()
    }

    /// Names of every metric in the snapshot (scalar and histogram).
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.scalars.iter().map(|(n, _, _)| n.clone()).collect();
        v.extend(self.hists.iter().map(|(n, _)| n.clone()));
        v
    }

    /// Render per-stage histogram summaries as a JSON array (used by
    /// serve-bench's `stage_breakdown`).
    pub fn stages_to_json(stages: &[(&'static str, LatencySnapshot)]) -> String {
        let mut arr = JsonArray::new();
        for (name, snap) in stages {
            let mut o = JsonObject::new();
            o.str("stage", name)
                .u64("count", snap.count())
                .u64("sum_micros", snap.sum_micros)
                .fixed("mean_micros", snap.mean_micros(), 1)
                .u64("p50_micros", snap.percentile_micros(50.0))
                .u64("p99_micros", snap.percentile_micros(99.0));
            arr.push_raw(&o.finish());
        }
        arr.finish()
    }
}

static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();

/// The process-wide registry every subsystem registers into and every
/// exporter consumer snapshots.
pub fn global() -> &'static MetricsRegistry {
    GLOBAL.get_or_init(MetricsRegistry::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::stats::LatencyHistogram;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn scalar_reader_sees_live_value() {
        let reg = MetricsRegistry::new();
        let c = Arc::new(AtomicU64::new(0));
        let c2 = Arc::clone(&c);
        reg.register_counter("hashdl_test_total", move || c2.load(Ordering::Relaxed) as f64);
        c.store(41, Ordering::Relaxed);
        let snap = reg.snapshot();
        assert_eq!(snap.scalars.len(), 1);
        assert_eq!(snap.scalars[0].2, 41.0);
        c.store(42, Ordering::Relaxed);
        assert_eq!(reg.snapshot().scalars[0].2, 42.0);
    }

    #[test]
    fn reregistering_a_name_replaces_not_duplicates() {
        let reg = MetricsRegistry::new();
        reg.register_gauge("g", || 1.0);
        reg.register_gauge("g", || 2.0);
        let snap = reg.snapshot();
        assert_eq!(snap.scalars.len(), 1);
        assert_eq!(snap.scalars[0].2, 2.0);
    }

    #[test]
    fn prometheus_histogram_is_cumulative_and_parses() {
        let reg = MetricsRegistry::new();
        let h = Arc::new(LatencyHistogram::new());
        h.record(3);
        h.record(3);
        h.record(1000);
        let h2 = Arc::clone(&h);
        reg.register_histogram("hashdl_lat_micros", move || h2.snapshot());
        reg.register_counter("hashdl_reqs_total", || 3.0);
        let text = reg.snapshot().to_prometheus();
        assert!(text.contains("# TYPE hashdl_reqs_total counter"));
        assert!(text.contains("hashdl_reqs_total 3"));
        assert!(text.contains("# TYPE hashdl_lat_micros histogram"));
        assert!(text.contains("hashdl_lat_micros_count 3"));
        assert!(text.contains("hashdl_lat_micros_sum 1006"));
        assert!(text.contains("le=\"+Inf\"} 3"));
        // cumulative: the last finite bucket must already hold all 3
        let inf_line = text.lines().find(|l| l.contains("+Inf")).unwrap();
        assert!(inf_line.ends_with(" 3"));
        // every non-comment line is "name value" or "name{labels} value"
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let mut parts = line.rsplitn(2, ' ');
            let val = parts.next().unwrap();
            assert!(val.parse::<f64>().is_ok(), "unparsable value in: {line}");
        }
    }

    #[test]
    fn json_snapshot_shape() {
        let reg = MetricsRegistry::new();
        reg.register_counter("c_total", || 5.0);
        reg.register_gauge("g_now", || 0.5);
        let h = LatencyHistogram::new();
        h.record(10);
        let snap_h = h.snapshot();
        reg.register_histogram("h_micros", move || snap_h.clone());
        let js = reg.snapshot().to_json();
        assert!(js.contains("\"counters\": {\"c_total\": 5}"));
        assert!(js.contains("\"g_now\": 0.5"));
        assert!(js.contains("\"h_micros\": {\"count\": 1"));
    }

    #[test]
    fn names_cover_both_kinds() {
        let reg = MetricsRegistry::new();
        reg.register_counter("a", || 0.0);
        reg.register_histogram("b", LatencySnapshot::default);
        let names = reg.snapshot().names();
        assert!(names.contains(&"a".to_string()));
        assert!(names.contains(&"b".to_string()));
    }
}
