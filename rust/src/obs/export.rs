//! One metrics registry, two output formats.
//!
//! Everything the stack measures — stage histograms, pool counters,
//! router stats, table health — registers a named *reader closure*
//! here; a snapshot walks the readers and renders Prometheus text
//! exposition or JSON. The registry holds closures, not values, so the
//! hot paths keep writing their own relaxed atomics and pay nothing for
//! being exported; the `Mutex` is touched only on register/snapshot.

use crate::serve::stats::LatencySnapshot;
use crate::util::json::{JsonArray, JsonObject};
use std::sync::{Mutex, OnceLock};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
}

impl MetricKind {
    fn prom(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
        }
    }
}

type ReadScalar = Box<dyn Fn() -> f64 + Send + Sync>;
type ReadHist = Box<dyn Fn() -> LatencySnapshot + Send + Sync>;

/// Render one `key="value"` label pair, sanitised for the exposition
/// format: quotes/backslashes escaped, whitespace collapsed to `_` (the
/// CI scrape parser splits lines on the last space, so label values must
/// never contain one).
pub fn label(key: &str, value: &str) -> String {
    let mut v = String::with_capacity(value.len());
    for c in value.chars() {
        match c {
            '"' => v.push_str("\\\""),
            '\\' => v.push_str("\\\\"),
            '\n' => v.push_str("\\n"),
            c if c.is_whitespace() => v.push('_'),
            c => v.push(c),
        }
    }
    format!("{key}=\"{v}\"")
}

/// Named counters/gauges/histograms, read lazily at snapshot time.
/// Scalars may carry a pre-rendered label set (`shard="0"`); the
/// identity a registration replaces is (name, labels) — pools come and
/// go in benches; the latest owner of an identity wins.
#[derive(Default)]
pub struct MetricsRegistry {
    scalars: Mutex<Vec<(String, String, MetricKind, ReadScalar)>>,
    hists: Mutex<Vec<(String, ReadHist)>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register_scalar(
        &self,
        name: &str,
        kind: MetricKind,
        read: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        self.register_labeled_scalar(name, "", kind, read);
    }

    /// Register one series of a labeled family. `labels` is the
    /// pre-rendered pair list without braces (`layer="0",shard="2"` —
    /// build pairs with [`label`]); `""` means an unlabeled metric.
    pub fn register_labeled_scalar(
        &self,
        name: &str,
        labels: &str,
        kind: MetricKind,
        read: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        let mut v = self.scalars.lock().unwrap();
        if let Some(slot) = v.iter_mut().find(|(n, l, _, _)| n == name && l == labels) {
            slot.2 = kind;
            slot.3 = Box::new(read);
        } else {
            v.push((name.to_string(), labels.to_string(), kind, Box::new(read)));
        }
    }

    pub fn register_counter(&self, name: &str, read: impl Fn() -> f64 + Send + Sync + 'static) {
        self.register_scalar(name, MetricKind::Counter, read);
    }

    pub fn register_gauge(&self, name: &str, read: impl Fn() -> f64 + Send + Sync + 'static) {
        self.register_scalar(name, MetricKind::Gauge, read);
    }

    pub fn register_labeled_counter(
        &self,
        name: &str,
        labels: &str,
        read: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        self.register_labeled_scalar(name, labels, MetricKind::Counter, read);
    }

    pub fn register_labeled_gauge(
        &self,
        name: &str,
        labels: &str,
        read: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        self.register_labeled_scalar(name, labels, MetricKind::Gauge, read);
    }

    pub fn register_histogram(
        &self,
        name: &str,
        read: impl Fn() -> LatencySnapshot + Send + Sync + 'static,
    ) {
        let mut v = self.hists.lock().unwrap();
        if let Some(slot) = v.iter_mut().find(|(n, _)| n == name) {
            slot.1 = Box::new(read);
        } else {
            v.push((name.to_string(), Box::new(read)));
        }
    }

    /// Read every registered metric once.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let scalars = self
            .scalars
            .lock()
            .unwrap()
            .iter()
            .map(|(n, l, k, f)| (n.clone(), l.clone(), *k, f()))
            .collect();
        let hists =
            self.hists.lock().unwrap().iter().map(|(n, f)| (n.clone(), f())).collect();
        MetricsSnapshot { scalars, hists }
    }
}

/// A point-in-time reading of every registered metric. Scalar tuples are
/// (name, labels, kind, value) with `labels == ""` for unlabeled
/// metrics.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub scalars: Vec<(String, String, MetricKind, f64)>,
    pub hists: Vec<(String, LatencySnapshot)>,
}

impl MetricsSnapshot {
    /// Prometheus text exposition format. Histograms render cumulative
    /// `_bucket{le=...}` series (only the occupied bounds plus `+Inf`),
    /// `_sum` and `_count`. Labeled scalar families are grouped under
    /// one `# TYPE` line; a registry with only unlabeled metrics renders
    /// byte-identically to the pre-label exporter.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        // Families in first-registration order, every series of a family
        // contiguous under its single TYPE line.
        let mut families: Vec<&str> = Vec::new();
        for (name, _, _, _) in &self.scalars {
            if !families.contains(&name.as_str()) {
                families.push(name.as_str());
            }
        }
        for family in families {
            let mut typed = false;
            for (name, labels, kind, v) in &self.scalars {
                if name.as_str() != family {
                    continue;
                }
                if !typed {
                    out.push_str(&format!("# TYPE {name} {}\n", kind.prom()));
                    typed = true;
                }
                let series = if labels.is_empty() {
                    name.clone()
                } else {
                    format!("{name}{{{labels}}}")
                };
                if *v == v.trunc() && v.abs() < 9.0e15 {
                    out.push_str(&format!("{series} {}\n", *v as i64));
                } else {
                    out.push_str(&format!("{series} {v}\n"));
                }
            }
        }
        for (name, snap) in &self.hists {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cum = 0u64;
            for (i, &c) in snap.counts.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                cum += c;
                out.push_str(&format!(
                    "{name}_bucket{{le=\"{}\"}} {cum}\n",
                    LatencySnapshot::bucket_upper(i)
                ));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", snap.count()));
            out.push_str(&format!("{name}_sum {}\n", snap.sum_micros));
            out.push_str(&format!("{name}_count {}\n", snap.count()));
        }
        out
    }

    /// JSON rendering: scalars verbatim (labeled series keyed as
    /// `name{labels}`), histograms summarised (count/sum/mean/p50/p99).
    pub fn to_json(&self) -> String {
        self.render_json(None)
    }

    /// [`Self::to_json`] plus a `series` field holding pre-rendered
    /// rollups (see `obs::series::SeriesStore::rollups_to_json`).
    pub fn to_json_with_series(&self, series_json: &str) -> String {
        self.render_json(Some(series_json))
    }

    fn render_json(&self, series_json: Option<&str>) -> String {
        let mut counters = JsonObject::new();
        let mut gauges = JsonObject::new();
        for (name, labels, kind, v) in &self.scalars {
            let key = if labels.is_empty() {
                name.clone()
            } else {
                format!("{name}{{{labels}}}")
            };
            match kind {
                MetricKind::Counter => counters.f64(&key, *v),
                MetricKind::Gauge => gauges.f64(&key, *v),
            };
        }
        let mut hists = JsonObject::new();
        for (name, snap) in &self.hists {
            let mut h = JsonObject::new();
            h.u64("count", snap.count())
                .u64("sum_micros", snap.sum_micros)
                .fixed("mean_micros", snap.mean_micros(), 1)
                .u64("p50_micros", snap.percentile_micros(50.0))
                .u64("p99_micros", snap.percentile_micros(99.0));
            hists.raw(name, &h.finish());
        }
        let mut o = JsonObject::new();
        o.raw("counters", &counters.finish())
            .raw("gauges", &gauges.finish())
            .raw("histograms", &hists.finish());
        if let Some(series) = series_json {
            o.raw("series", series);
        }
        o.finish()
    }

    /// Qualified names of every metric in the snapshot (scalar series as
    /// `name` or `name{labels}`, plus histograms).
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .scalars
            .iter()
            .map(|(n, l, _, _)| {
                if l.is_empty() {
                    n.clone()
                } else {
                    format!("{n}{{{l}}}")
                }
            })
            .collect();
        v.extend(self.hists.iter().map(|(n, _)| n.clone()));
        v
    }

    /// Render per-stage histogram summaries as a JSON array (used by
    /// serve-bench's `stage_breakdown`).
    pub fn stages_to_json(stages: &[(&'static str, LatencySnapshot)]) -> String {
        let mut arr = JsonArray::new();
        for (name, snap) in stages {
            let mut o = JsonObject::new();
            o.str("stage", name)
                .u64("count", snap.count())
                .u64("sum_micros", snap.sum_micros)
                .fixed("mean_micros", snap.mean_micros(), 1)
                .u64("p50_micros", snap.percentile_micros(50.0))
                .u64("p99_micros", snap.percentile_micros(99.0));
            arr.push_raw(&o.finish());
        }
        arr.finish()
    }
}

static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();

/// The process-wide registry every subsystem registers into and every
/// exporter consumer snapshots.
pub fn global() -> &'static MetricsRegistry {
    GLOBAL.get_or_init(MetricsRegistry::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::stats::LatencyHistogram;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn scalar_reader_sees_live_value() {
        let reg = MetricsRegistry::new();
        let c = Arc::new(AtomicU64::new(0));
        let c2 = Arc::clone(&c);
        reg.register_counter("hashdl_test_total", move || c2.load(Ordering::Relaxed) as f64);
        c.store(41, Ordering::Relaxed);
        let snap = reg.snapshot();
        assert_eq!(snap.scalars.len(), 1);
        assert_eq!(snap.scalars[0].3, 41.0);
        c.store(42, Ordering::Relaxed);
        assert_eq!(reg.snapshot().scalars[0].3, 42.0);
    }

    #[test]
    fn reregistering_a_name_replaces_not_duplicates() {
        let reg = MetricsRegistry::new();
        reg.register_gauge("g", || 1.0);
        reg.register_gauge("g", || 2.0);
        let snap = reg.snapshot();
        assert_eq!(snap.scalars.len(), 1);
        assert_eq!(snap.scalars[0].3, 2.0);
    }

    #[test]
    fn labeled_series_share_one_type_line_and_distinct_identities() {
        let reg = MetricsRegistry::new();
        reg.register_labeled_gauge("hashdl_table_skew", &label("shard", "0"), || 1.5);
        reg.register_labeled_gauge("hashdl_table_skew", &label("shard", "1"), || 2.5);
        // Same (name, labels) replaces; different labels coexist.
        reg.register_labeled_gauge("hashdl_table_skew", &label("shard", "0"), || 1.25);
        let snap = reg.snapshot();
        assert_eq!(snap.scalars.len(), 2);
        let text = snap.to_prometheus();
        assert_eq!(
            text.matches("# TYPE hashdl_table_skew gauge").count(),
            1,
            "one TYPE line per family:\n{text}"
        );
        assert!(text.contains("hashdl_table_skew{shard=\"0\"} 1.25"), "{text}");
        assert!(text.contains("hashdl_table_skew{shard=\"1\"} 2.5"), "{text}");
        let js = snap.to_json();
        assert!(js.contains("\"hashdl_table_skew{shard=\\\"0\\\"}\": 1.25"), "{js}");
    }

    #[test]
    fn unlabeled_only_output_is_unchanged_by_label_support() {
        // The exact pre-label rendering: one TYPE line then one sample
        // line per scalar, in registration order.
        let reg = MetricsRegistry::new();
        reg.register_counter("a_total", || 3.0);
        reg.register_gauge("b_now", || 0.5);
        assert_eq!(
            reg.snapshot().to_prometheus(),
            "# TYPE a_total counter\na_total 3\n# TYPE b_now gauge\nb_now 0.5\n"
        );
    }

    #[test]
    fn label_sanitises_hostile_values() {
        assert_eq!(label("model", "m0"), "model=\"m0\"");
        assert_eq!(label("model", "a b"), "model=\"a_b\"");
        assert_eq!(label("model", "q\"uote"), "model=\"q\\\"uote\"");
        assert_eq!(label("model", "back\\slash"), "model=\"back\\\\slash\"");
    }

    #[test]
    fn json_with_series_appends_the_rollups() {
        let reg = MetricsRegistry::new();
        reg.register_counter("c_total", || 1.0);
        let js = reg.snapshot().to_json_with_series("[{\"name\": \"c_total\"}]");
        assert!(js.contains("\"series\": [{\"name\": \"c_total\"}]"), "{js}");
        assert!(!reg.snapshot().to_json().contains("series"), "plain to_json stays plain");
    }

    #[test]
    fn prometheus_histogram_is_cumulative_and_parses() {
        let reg = MetricsRegistry::new();
        let h = Arc::new(LatencyHistogram::new());
        h.record(3);
        h.record(3);
        h.record(1000);
        let h2 = Arc::clone(&h);
        reg.register_histogram("hashdl_lat_micros", move || h2.snapshot());
        reg.register_counter("hashdl_reqs_total", || 3.0);
        let text = reg.snapshot().to_prometheus();
        assert!(text.contains("# TYPE hashdl_reqs_total counter"));
        assert!(text.contains("hashdl_reqs_total 3"));
        assert!(text.contains("# TYPE hashdl_lat_micros histogram"));
        assert!(text.contains("hashdl_lat_micros_count 3"));
        assert!(text.contains("hashdl_lat_micros_sum 1006"));
        assert!(text.contains("le=\"+Inf\"} 3"));
        // cumulative: the last finite bucket must already hold all 3
        let inf_line = text.lines().find(|l| l.contains("+Inf")).unwrap();
        assert!(inf_line.ends_with(" 3"));
        // every non-comment line is "name value" or "name{labels} value"
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let mut parts = line.rsplitn(2, ' ');
            let val = parts.next().unwrap();
            assert!(val.parse::<f64>().is_ok(), "unparsable value in: {line}");
        }
    }

    #[test]
    fn json_snapshot_shape() {
        let reg = MetricsRegistry::new();
        reg.register_counter("c_total", || 5.0);
        reg.register_gauge("g_now", || 0.5);
        let h = LatencyHistogram::new();
        h.record(10);
        let snap_h = h.snapshot();
        reg.register_histogram("h_micros", move || snap_h.clone());
        let js = reg.snapshot().to_json();
        assert!(js.contains("\"counters\": {\"c_total\": 5}"));
        assert!(js.contains("\"g_now\": 0.5"));
        assert!(js.contains("\"h_micros\": {\"count\": 1"));
    }

    #[test]
    fn names_cover_both_kinds() {
        let reg = MetricsRegistry::new();
        reg.register_counter("a", || 0.0);
        reg.register_histogram("b", LatencySnapshot::default);
        let names = reg.snapshot().names();
        assert!(names.contains(&"a".to_string()));
        assert!(names.contains(&"b".to_string()));
    }
}
