//! Std-only HTTP/1.1 observability listener (`--obs-listen ADDR`).
//!
//! A minimal single-purpose front door for the metrics registry and the
//! event journal — GET only, one short-lived connection at a time,
//! `Connection: close` on every response. Routes:
//!
//! | route           | body                                            |
//! |-----------------|-------------------------------------------------|
//! | `/metrics`      | Prometheus text exposition of the registry      |
//! | `/metrics.json` | the same snapshot as JSON, plus series rollups  |
//! | `/events?n=K`   | newest K journal events as JSONL (default 256)  |
//! | `/health`       | liveness JSON (uptime, event/alert totals)      |
//!
//! This is deliberately not the ROADMAP's request-serving front door:
//! no keep-alive, no pipelining, no POST — a scrape endpoint, built so
//! the drift observatory is watchable while `train-serve`/`serve-bench`
//! run. The acceptor thread is detached; it dies with the process.

use crate::util::json::JsonObject;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

/// How many journal events `/events` returns when `?n=` is absent.
pub const DEFAULT_EVENT_TAIL: usize = 256;

/// Handle onto a running listener (the accept loop owns the socket).
pub struct ObsServer {
    addr: SocketAddr,
}

impl ObsServer {
    /// The bound address — useful with port 0 (tests bind
    /// `127.0.0.1:0` and read the assigned port here).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

/// Bind `addr` and spawn the accept loop. Returns once the socket is
/// bound, so a scrape immediately after `serve` succeeds.
pub fn serve(addr: &str) -> io::Result<ObsServer> {
    let listener = TcpListener::bind(addr)?;
    let bound = listener.local_addr()?;
    std::thread::Builder::new()
        .name("hashdl-obs-http".into())
        .spawn(move || accept_loop(&listener))
        .map_err(|e| io::Error::new(io::ErrorKind::Other, e))?;
    Ok(ObsServer { addr: bound })
}

fn accept_loop(listener: &TcpListener) {
    for conn in listener.incoming() {
        match conn {
            Ok(stream) => {
                // One tiny request per connection; a stalled client must
                // not wedge the scrape endpoint forever.
                let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
                let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
                let _ = handle_connection(stream);
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn handle_connection(mut stream: TcpStream) -> io::Result<()> {
    let mut buf = [0u8; 4096];
    let mut req = Vec::new();
    // Read until the end of the request head (we ignore bodies — GET
    // only) or the buffer limit.
    loop {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            break;
        }
        req.extend_from_slice(&buf[..n]);
        if req.windows(4).any(|w| w == b"\r\n\r\n") || req.len() > 16 * 1024 {
            break;
        }
    }
    let head = String::from_utf8_lossy(&req);
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");
    let (status, content_type, body) = if method != "GET" {
        (405, "text/plain; charset=utf-8", "method not allowed\n".to_string())
    } else {
        respond(target)
    };
    let reason = match status {
        200 => "OK",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Route a GET target to (status, content type, body). Split out from
/// the socket plumbing so tests exercise routing directly.
pub fn respond(target: &str) -> (u16, &'static str, String) {
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    match path {
        "/metrics" => (
            200,
            "text/plain; version=0.0.4; charset=utf-8",
            crate::obs::global().snapshot().to_prometheus(),
        ),
        "/metrics.json" => {
            crate::obs::series::sample_global_now();
            let body = crate::obs::global()
                .snapshot()
                .to_json_with_series(&crate::obs::series::store().rollups_to_json());
            (200, "application/json", body)
        }
        "/events" => {
            let n = query
                .split('&')
                .find_map(|kv| kv.strip_prefix("n="))
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(DEFAULT_EVENT_TAIL);
            (200, "application/x-ndjson", crate::obs::events::journal().to_jsonl(n))
        }
        "/health" => {
            let mut o = JsonObject::new();
            o.str("status", "ok")
                .u64("uptime_micros", crate::obs::uptime_micros())
                .u64("events_total", crate::obs::events::journal().total())
                .u64("drift_alerts_total", crate::obs::drift::drift_alerts_total())
                .u64("adaptive_rebuilds_total", crate::obs::drift::adaptive_rebuilds_total());
            (200, "application/json", o.finish() + "\n")
        }
        _ => (404, "text/plain; charset=utf-8", "not found\n".to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_cover_the_contract() {
        let (code, ct, body) = respond("/health");
        assert_eq!(code, 200);
        assert_eq!(ct, "application/json");
        assert!(body.contains("\"status\": \"ok\""));

        let (code, ct, _) = respond("/metrics");
        assert_eq!(code, 200);
        assert!(ct.starts_with("text/plain"));

        let (code, _, body) = respond("/metrics.json");
        assert_eq!(code, 200);
        assert!(body.contains("\"counters\""));
        assert!(body.contains("\"series\""));

        let (code, ct, _) = respond("/events?n=5");
        assert_eq!(code, 200);
        assert_eq!(ct, "application/x-ndjson");

        let (code, _, _) = respond("/nope");
        assert_eq!(code, 404);
    }

    #[test]
    fn events_query_parses_and_defaults() {
        crate::obs::events::journal();
        // Unparsable / absent n falls back to the default tail.
        let (code, _, _) = respond("/events?n=zebra");
        assert_eq!(code, 200);
        let (code, _, _) = respond("/events");
        assert_eq!(code, 200);
    }

    #[test]
    fn server_binds_and_answers_over_tcp() {
        let srv = serve("127.0.0.1:0").expect("bind");
        let mut conn = TcpStream::connect(srv.local_addr()).expect("connect");
        conn.write_all(b"GET /health HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        assert!(resp.contains("\"status\": \"ok\""));
    }
}
