//! Stage vocabulary and per-request span trees.
//!
//! Every unit of work on the request path is attributed to one [`Stage`].
//! The global per-stage histograms (`crate::obs::stages`) aggregate all
//! of them; on top of that, `--trace-sample N` activates a thread-local
//! trace for every Nth micro-batch, capturing each stage's start offset,
//! duration and nesting depth as a [`Trace`] the worker emits whole.
//!
//! The thread-local is the trick that keeps tracing free when idle: the
//! scoped timers in `crate::obs` consult it with one `RefCell` borrow
//! only after the cheap enabled check, and when no trace is active the
//! borrow finds `None` and returns immediately.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// One stage of the request path (training adds the backward stages).
/// The order here is pipeline order — reports iterate [`STAGES`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Request sat in the pool's bounded queue (enqueue → batch claim).
    Queue,
    /// Worker re-pinned its workspace to the newest published epoch.
    EpochPin,
    /// Layer inputs densified into the query plane.
    Densify,
    /// One-pass fingerprint hashing of the whole batch.
    HashFp,
    /// Multi-probe bucket collection + multiplicity ranking (+ §5.4
    /// re-rank) per sample.
    ProbeRank,
    /// Fused union-major (or sample-major) sparse forward gather.
    Gather,
    /// Dense output layer over all classes.
    Output,
    /// Backward pass + gradient application (training only).
    Backprop,
}

pub const N_STAGES: usize = 8;

/// All stages in pipeline order.
pub const STAGES: [Stage; N_STAGES] = [
    Stage::Queue,
    Stage::EpochPin,
    Stage::Densify,
    Stage::HashFp,
    Stage::ProbeRank,
    Stage::Gather,
    Stage::Output,
    Stage::Backprop,
];

impl Stage {
    /// Stable metric-name component (`hashdl_stage_<name>_micros`).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::EpochPin => "epoch_pin",
            Stage::Densify => "densify",
            Stage::HashFp => "hash",
            Stage::ProbeRank => "probe_rank",
            Stage::Gather => "gather",
            Stage::Output => "output",
            Stage::Backprop => "backprop",
        }
    }

    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }
}

/// One completed span inside a trace.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub stage: Stage,
    /// Nesting depth at the time the span opened (0 = top level).
    pub depth: usize,
    /// Offset from the trace start, microseconds.
    pub start_micros: u64,
    pub dur_micros: u64,
}

/// A full span tree for one sampled micro-batch, events in start order.
#[derive(Clone, Debug)]
pub struct Trace {
    pub id: u64,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Indented one-line-per-span rendering (what `--trace-sample` emits
    /// to stderr).
    pub fn render(&self) -> String {
        let mut s = format!("[trace {}] {} spans", self.id, self.events.len());
        for e in &self.events {
            s.push('\n');
            for _ in 0..=e.depth {
                s.push_str("  ");
            }
            s.push_str(&format!(
                "{:<10} +{:>6}us {:>6}us",
                e.stage.name(),
                e.start_micros,
                e.dur_micros
            ));
        }
        s
    }
}

struct TraceState {
    id: u64,
    t0: Instant,
    open: Vec<Stage>,
    events: Vec<TraceEvent>,
}

thread_local! {
    static ACTIVE: RefCell<Option<TraceState>> = const { RefCell::new(None) };
}

/// Begin collecting a span tree on this thread (replaces any active one).
pub fn trace_begin(id: u64) {
    ACTIVE.with(|a| {
        *a.borrow_mut() =
            Some(TraceState { id, t0: Instant::now(), open: Vec::new(), events: Vec::new() })
    });
}

/// Finish the active trace and return it (events sorted into start
/// order); `None` if no trace was active on this thread.
pub fn trace_end() -> Option<Trace> {
    ACTIVE.with(|a| a.borrow_mut().take()).map(|st| {
        let mut events = st.events;
        events.sort_by_key(|e| (e.start_micros, e.depth));
        Trace { id: st.id, events }
    })
}

pub fn trace_active() -> bool {
    ACTIVE.with(|a| a.borrow().is_some())
}

/// Called by the scoped timers when a span opens.
pub(crate) fn note_open(stage: Stage) {
    ACTIVE.with(|a| {
        if let Some(st) = a.borrow_mut().as_mut() {
            st.open.push(stage);
        }
    });
}

/// Called by the scoped timers when a span closes. A span that opened
/// before the trace began (stale stack top) is recorded at the current
/// depth with its start clamped to the trace origin.
pub(crate) fn note_close(stage: Stage, start: Instant, dur_micros: u64) {
    ACTIVE.with(|a| {
        if let Some(st) = a.borrow_mut().as_mut() {
            if st.open.last() == Some(&stage) {
                st.open.pop();
            }
            let depth = st.open.len();
            let start_micros = start.saturating_duration_since(st.t0).as_micros() as u64;
            st.events.push(TraceEvent { stage, depth, start_micros, dur_micros });
        }
    });
}

// --- sampling cadence -------------------------------------------------

static TRACE_EVERY: AtomicU64 = AtomicU64::new(0);
static TRACE_TICK: AtomicU64 = AtomicU64::new(0);

/// Emit a full span tree for every `n`th micro-batch (0 disables —
/// the default).
pub fn set_trace_every(n: u64) {
    TRACE_EVERY.store(n, Ordering::Relaxed);
}

/// Should the next micro-batch be traced? Increments the global tick.
pub fn trace_due() -> bool {
    let n = TRACE_EVERY.load(Ordering::Relaxed);
    if n == 0 {
        return false;
    }
    TRACE_TICK.fetch_add(1, Ordering::Relaxed) % n == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_indices_match_pipeline_order() {
        for (i, s) in STAGES.iter().enumerate() {
            assert_eq!(s.index(), i);
        }
        let mut names: Vec<&str> = STAGES.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), N_STAGES, "stage names must be distinct");
    }

    #[test]
    fn trace_due_fires_every_nth() {
        // The tick is global; only relative behaviour is assertable.
        set_trace_every(0);
        assert!(!trace_due());
        set_trace_every(1);
        assert!(trace_due());
        assert!(trace_due());
        set_trace_every(0);
        assert!(!trace_due());
    }

    #[test]
    fn render_mentions_every_span() {
        let t = Trace {
            id: 7,
            events: vec![
                TraceEvent { stage: Stage::HashFp, depth: 0, start_micros: 0, dur_micros: 5 },
                TraceEvent { stage: Stage::Gather, depth: 1, start_micros: 6, dur_micros: 2 },
            ],
        };
        let r = t.render();
        assert!(r.contains("trace 7"));
        assert!(r.contains("hash"));
        assert!(r.contains("gather"));
    }
}
