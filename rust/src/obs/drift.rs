//! Drift detection over health snapshots and metric series, and the
//! rebuild policy it feeds.
//!
//! The SLIDE problem this repo keeps circling: selection quality decays
//! as weights move away from the tables that indexed them, and a fixed
//! `rebuild_every_epochs` either wastes rebuilds or serves stale tables.
//! This module watches the signals PR 7 created — recall estimates,
//! bucket-occupancy skew, empty-bucket fraction, rebuild age, serving
//! version-age — and turns them into:
//!
//! * [`DriftAlert`]s, journaled as `drift_alert` events and counted in
//!   `hashdl_drift_alerts_total`;
//! * a rebuild verdict for [`RebuildPolicy::HealthDriven`] selectors
//!   (consulted by `LshSelector::on_epoch_end` and
//!   `ShardedLayerTables::maybe_rebuild_staggered`).
//!
//! [`RebuildPolicy::Fixed`] never consults a detector: its code path is
//! bit-for-bit the pre-observatory cadence (pinned by
//! `tests/observatory.rs`). Detectors draw no RNG and mutate nothing but
//! their own windows, so even `HealthDriven` only changes *when* tables
//! rebuild, never what a given rebuild produces.

use crate::obs::events::{self, EventKind};
use crate::obs::health::TableHealth;
use crate::obs::series::SeriesStore;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// When do hash tables rebuild?
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RebuildPolicy {
    /// Every `rebuild_every_epochs` epochs — the pre-observatory
    /// behaviour, bit-for-bit.
    #[default]
    Fixed,
    /// The fixed cadence still applies, but drift detectors may force an
    /// earlier rebuild when selection quality decays.
    HealthDriven,
}

impl RebuildPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fixed" => Some(RebuildPolicy::Fixed),
            "health" | "health-driven" => Some(RebuildPolicy::HealthDriven),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RebuildPolicy::Fixed => "fixed",
            RebuildPolicy::HealthDriven => "health",
        }
    }
}

/// Detector thresholds. All windows are in observations (one per epoch
/// for the trainer-side detector, one per sampler tick for the series
/// scanner).
#[derive(Clone, Copy, Debug)]
pub struct DriftConfig {
    /// Observations forming the baseline (immediately before the recent
    /// window).
    pub baseline_window: usize,
    /// Observations forming the "now" window.
    pub recent_window: usize,
    /// Alert when recent recall < baseline recall − this.
    pub recall_drop: f64,
    /// Alert when recent skew > baseline skew × this.
    pub skew_growth: f64,
    /// Alert when recent empty-bucket fraction > baseline + this.
    pub empty_rise: f64,
    /// Alert when the serving stale fraction (version-age > 0) exceeds
    /// this.
    pub stale_tail: f64,
    /// Hard staleness backstop: alert when a table has gone this many
    /// selection batches without a rebuild (0 disables).
    pub max_rebuild_age_batches: u64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig {
            baseline_window: 4,
            recent_window: 2,
            recall_drop: 0.1,
            skew_growth: 1.5,
            empty_rise: 0.15,
            stale_tail: 0.5,
            max_rebuild_age_batches: 0,
        }
    }
}

/// One tripped detector.
#[derive(Clone, Debug)]
pub struct DriftAlert {
    /// What was watched (`recall_estimate`, `occupancy_skew`, …,
    /// qualified with the series key for the scanner).
    pub metric: String,
    pub baseline: f64,
    pub recent: f64,
    /// Human-readable trigger description.
    pub reason: String,
}

impl DriftAlert {
    fn journal(&self) {
        let n = counters().alerts.fetch_add(1, Ordering::Relaxed) + 1;
        events::emit(EventKind::DriftAlert, &self.metric, n, &self.reason);
    }
}

/// What a detector pass concluded.
#[derive(Debug, Default)]
pub struct DriftDecision {
    pub rebuild_due: bool,
    pub alerts: Vec<DriftAlert>,
}

struct DriftCounters {
    alerts: AtomicU64,
    adaptive_rebuilds: AtomicU64,
}

/// Global alert/adaptive-rebuild counters; first call registers them
/// into the metrics registry.
fn counters() -> &'static DriftCounters {
    static C: OnceLock<DriftCounters> = OnceLock::new();
    static REG: OnceLock<()> = OnceLock::new();
    let c: &'static DriftCounters = C.get_or_init(|| DriftCounters {
        alerts: AtomicU64::new(0),
        adaptive_rebuilds: AtomicU64::new(0),
    });
    REG.get_or_init(|| {
        crate::obs::export::global().register_counter("hashdl_drift_alerts_total", || {
            counters().alerts.load(Ordering::Relaxed) as f64
        });
        crate::obs::export::global().register_counter("hashdl_adaptive_rebuilds_total", || {
            counters().adaptive_rebuilds.load(Ordering::Relaxed) as f64
        });
    });
    c
}

pub fn drift_alerts_total() -> u64 {
    counters().alerts.load(Ordering::Relaxed)
}

pub fn adaptive_rebuilds_total() -> u64 {
    counters().adaptive_rebuilds.load(Ordering::Relaxed)
}

/// Record one health-driven rebuild that the fixed cadence would not
/// have done: bumps `hashdl_adaptive_rebuilds_total` and journals a
/// `rebuild` event with subject `"adaptive"`.
pub fn note_adaptive_rebuild(what: &str) {
    let n = counters().adaptive_rebuilds.fetch_add(1, Ordering::Relaxed) + 1;
    events::emit(EventKind::Rebuild, "adaptive", n, what);
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Split a history into (baseline mean, recent mean); `None` until
/// enough observations exist.
fn baseline_recent(vals: &[f64], cfg: &DriftConfig) -> Option<(f64, f64)> {
    let need = cfg.baseline_window + cfg.recent_window;
    if vals.len() < need.max(2) {
        return None;
    }
    let recent = &vals[vals.len() - cfg.recent_window..];
    let base = &vals[vals.len() - need..vals.len() - cfg.recent_window];
    Some((mean(base), mean(recent)))
}

fn check_recall_drop(metric: &str, vals: &[f64], cfg: &DriftConfig) -> Option<DriftAlert> {
    let (base, recent) = baseline_recent(vals, cfg)?;
    (recent < base - cfg.recall_drop).then(|| DriftAlert {
        metric: metric.to_string(),
        baseline: base,
        recent,
        reason: format!("recall dropped {base:.4} -> {recent:.4} (> {:.4})", cfg.recall_drop),
    })
}

fn check_skew_growth(metric: &str, vals: &[f64], cfg: &DriftConfig) -> Option<DriftAlert> {
    let (base, recent) = baseline_recent(vals, cfg)?;
    (base > 0.0 && recent > base * cfg.skew_growth).then(|| DriftAlert {
        metric: metric.to_string(),
        baseline: base,
        recent,
        reason: format!("occupancy skew grew {base:.2} -> {recent:.2} (x{:.2})", cfg.skew_growth),
    })
}

fn check_empty_rise(metric: &str, vals: &[f64], cfg: &DriftConfig) -> Option<DriftAlert> {
    let (base, recent) = baseline_recent(vals, cfg)?;
    (recent > base + cfg.empty_rise).then(|| DriftAlert {
        metric: metric.to_string(),
        baseline: base,
        recent,
        reason: format!(
            "empty-bucket fraction rose {base:.4} -> {recent:.4} (+{:.4})",
            cfg.empty_rise
        ),
    })
}

/// Stateful per-table detector fed one [`TableHealth`] per epoch by a
/// `HealthDriven` selector. On a trip it journals the alerts, reports
/// `rebuild_due`, and resets its windows so the post-rebuild state forms
/// the next baseline.
#[derive(Debug)]
pub struct HealthDriftDetector {
    cfg: DriftConfig,
    label: String,
    recall: Vec<f64>,
    skew: Vec<f64>,
    empty: Vec<f64>,
}

impl HealthDriftDetector {
    pub fn new(label: &str, cfg: DriftConfig) -> Self {
        counters();
        HealthDriftDetector {
            cfg,
            label: label.to_string(),
            recall: Vec::new(),
            skew: Vec::new(),
            empty: Vec::new(),
        }
    }

    pub fn config(&self) -> &DriftConfig {
        &self.cfg
    }

    /// Fold one health snapshot in and decide whether drift warrants a
    /// rebuild now.
    pub fn observe(&mut self, h: &TableHealth) -> DriftDecision {
        let mut alerts = Vec::new();
        if self.cfg.max_rebuild_age_batches > 0
            && h.rebuild_age_batches >= self.cfg.max_rebuild_age_batches
        {
            alerts.push(DriftAlert {
                metric: format!("{}/rebuild_age_batches", self.label),
                baseline: self.cfg.max_rebuild_age_batches as f64,
                recent: h.rebuild_age_batches as f64,
                reason: format!(
                    "table stale for {} selection batches (cap {})",
                    h.rebuild_age_batches, self.cfg.max_rebuild_age_batches
                ),
            });
        }
        if h.recall_trials > 0 {
            self.recall.push(h.recall_estimate);
        }
        self.skew.push(h.occupancy_skew);
        self.empty.push(h.empty_bucket_fraction);

        let recall_key = format!("{}/recall_estimate", self.label);
        let skew_key = format!("{}/occupancy_skew", self.label);
        let empty_key = format!("{}/empty_bucket_fraction", self.label);
        alerts.extend(check_recall_drop(&recall_key, &self.recall, &self.cfg));
        alerts.extend(check_skew_growth(&skew_key, &self.skew, &self.cfg));
        alerts.extend(check_empty_rise(&empty_key, &self.empty, &self.cfg));

        let rebuild_due = !alerts.is_empty();
        if rebuild_due {
            for a in &alerts {
                a.journal();
            }
            // The rebuild the caller is about to do invalidates the old
            // windows: post-rebuild health becomes the next baseline.
            self.recall.clear();
            self.skew.clear();
            self.empty.clear();
        }
        DriftDecision { rebuild_due, alerts }
    }
}

/// Stateless-per-scan series checks with a per-series cooldown: the
/// sampler calls [`scan`](SeriesMonitor::scan) each tick; a series that
/// alerted stays quiet until it has accumulated a fresh baseline's worth
/// of new samples.
pub struct SeriesMonitor {
    cfg: DriftConfig,
    /// (series key, ring total at last alert).
    cooldown: Vec<(String, u64)>,
}

impl SeriesMonitor {
    pub fn new(cfg: DriftConfig) -> Self {
        counters();
        SeriesMonitor { cfg, cooldown: Vec::new() }
    }

    fn in_cooldown(&self, key: &str, total: u64) -> bool {
        self.cooldown.iter().any(|(k, at)| {
            k == key && total < at + (self.cfg.baseline_window + self.cfg.recent_window) as u64
        })
    }

    fn note_fired(&mut self, key: &str, total: u64) {
        if let Some(slot) = self.cooldown.iter_mut().find(|(k, _)| k == key) {
            slot.1 = total;
        } else {
            self.cooldown.push((key.to_string(), total));
        }
    }

    /// Check every series in `store` against the detector suite, journal
    /// and return whatever tripped.
    pub fn scan(&mut self, store: &SeriesStore) -> Vec<DriftAlert> {
        let mut fired = Vec::new();
        for (key, _kind, ring) in store.all() {
            let total = ring.total();
            if self.in_cooldown(&key, total) {
                continue;
            }
            let vals: Vec<f64> = ring.window().iter().map(|p| p.value).collect();
            let alert = if key.contains("recall_estimate") {
                check_recall_drop(&key, &vals, &self.cfg)
            } else if key.contains("occupancy_skew") {
                check_skew_growth(&key, &vals, &self.cfg)
            } else if key.contains("empty_bucket_fraction") {
                check_empty_rise(&key, &vals, &self.cfg)
            } else if key.contains("stale_fraction") {
                // Version-age tail mass: alert while the fraction of
                // micro-batches served from a stale version exceeds the
                // configured tail.
                vals.last().copied().filter(|&v| v > self.cfg.stale_tail).map(|v| DriftAlert {
                    metric: key.clone(),
                    baseline: self.cfg.stale_tail,
                    recent: v,
                    reason: format!("stale-serve fraction {v:.4} above tail {:.4}", self.cfg.stale_tail),
                })
            } else {
                None
            };
            if let Some(a) = alert {
                a.journal();
                self.note_fired(&key, total);
                fired.push(a);
            }
        }
        fired
    }
}

/// Run the global series monitor over the global store (called by the
/// background sampler each tick).
pub fn scan_global_series() {
    static MON: OnceLock<Mutex<SeriesMonitor>> = OnceLock::new();
    let mon = MON.get_or_init(|| Mutex::new(SeriesMonitor::new(DriftConfig::default())));
    if let Ok(mut m) = mon.lock() {
        m.scan(crate::obs::series::store());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn health(recall: f64, trials: u64, skew: f64, empty: f64, age: u64) -> TableHealth {
        TableHealth {
            recall_estimate: recall,
            recall_trials: trials,
            occupancy_skew: skew,
            empty_bucket_fraction: empty,
            rebuild_age_batches: age,
            ..TableHealth::default()
        }
    }

    fn cfg() -> DriftConfig {
        DriftConfig { baseline_window: 3, recent_window: 2, ..DriftConfig::default() }
    }

    #[test]
    fn flat_health_never_trips() {
        let mut d = HealthDriftDetector::new("l0", cfg());
        for _ in 0..20 {
            let dec = d.observe(&health(0.9, 10, 1.2, 0.3, 5));
            assert!(!dec.rebuild_due, "flat series must stay quiet");
        }
    }

    #[test]
    fn recall_drop_trips_and_resets_windows() {
        let mut d = HealthDriftDetector::new("l0", cfg());
        for _ in 0..4 {
            assert!(!d.observe(&health(0.9, 10, 1.2, 0.3, 5)).rebuild_due);
        }
        // Two decayed observations: recent mean 0.55 vs baseline 0.9.
        assert!(!d.observe(&health(0.55, 10, 1.2, 0.3, 5)).rebuild_due, "one sample is noise");
        let dec = d.observe(&health(0.55, 10, 1.2, 0.3, 5));
        assert!(dec.rebuild_due, "sustained recall drop must trip");
        assert_eq!(dec.alerts.len(), 1);
        assert!(dec.alerts[0].metric.contains("recall_estimate"));
        assert!(dec.alerts[0].recent < dec.alerts[0].baseline);
        // Windows reset: the very next observation cannot re-trip.
        assert!(!d.observe(&health(0.55, 10, 1.2, 0.3, 5)).rebuild_due);
    }

    #[test]
    fn skew_growth_and_empty_rise_trip() {
        let mut d = HealthDriftDetector::new("l1", cfg());
        for _ in 0..5 {
            d.observe(&health(0.9, 10, 1.2, 0.1, 5));
        }
        let dec = d.observe(&health(0.9, 10, 4.0, 0.5, 5));
        // One hot sample may already push the 2-wide recent mean over
        // both thresholds; a second certainly does.
        let dec = if dec.rebuild_due { dec } else { d.observe(&health(0.9, 10, 4.0, 0.5, 5)) };
        assert!(dec.rebuild_due);
        let metrics: Vec<&str> = dec.alerts.iter().map(|a| a.metric.as_str()).collect();
        assert!(metrics.iter().any(|m| m.contains("occupancy_skew")), "{metrics:?}");
        assert!(metrics.iter().any(|m| m.contains("empty_bucket_fraction")), "{metrics:?}");
    }

    #[test]
    fn age_backstop_trips_without_windows() {
        let mut d = HealthDriftDetector::new(
            "l0",
            DriftConfig { max_rebuild_age_batches: 100, ..cfg() },
        );
        assert!(!d.observe(&health(0.0, 0, 1.0, 0.1, 99)).rebuild_due);
        let dec = d.observe(&health(0.0, 0, 1.0, 0.1, 100));
        assert!(dec.rebuild_due, "age cap is an immediate backstop");
        assert!(dec.alerts[0].metric.contains("rebuild_age"));
    }

    #[test]
    fn series_monitor_trips_on_drop_with_cooldown() {
        use crate::obs::export::MetricKind;
        use crate::obs::series::SeriesStore;
        let store = SeriesStore::with_capacity(32);
        let reg = crate::obs::export::MetricsRegistry::new();
        let v = std::sync::Arc::new(Mutex::new(0.9f64));
        let v2 = std::sync::Arc::clone(&v);
        reg.register_labeled_gauge("hashdl_table_recall_estimate", "layer=\"0\"", move || {
            *v2.lock().unwrap()
        });
        let mut mon = SeriesMonitor::new(cfg());
        for t in 0..5u64 {
            store.sample(&reg.snapshot(), t * 1000);
            assert!(mon.scan(&store).is_empty(), "flat series must stay quiet");
        }
        *v.lock().unwrap() = 0.4;
        store.sample(&reg.snapshot(), 6000);
        store.sample(&reg.snapshot(), 7000);
        let fired = mon.scan(&store);
        assert_eq!(fired.len(), 1, "drop must fire exactly once");
        assert!(fired[0].metric.contains("recall_estimate"));
        // Cooldown: the same decayed window cannot re-fire immediately.
        assert!(mon.scan(&store).is_empty());
        let _ = MetricKind::Gauge;
    }

    #[test]
    fn stale_fraction_threshold() {
        let store = crate::obs::series::SeriesStore::with_capacity(8);
        let reg = crate::obs::export::MetricsRegistry::new();
        reg.register_gauge("hashdl_pool_version_age_stale_fraction", || 0.8);
        store.sample(&reg.snapshot(), 100);
        let mut mon = SeriesMonitor::new(cfg());
        let fired = mon.scan(&store);
        assert_eq!(fired.len(), 1);
        assert!(fired[0].reason.contains("stale-serve"));
    }

    #[test]
    fn policy_parses() {
        assert_eq!(RebuildPolicy::parse("fixed"), Some(RebuildPolicy::Fixed));
        assert_eq!(RebuildPolicy::parse("health"), Some(RebuildPolicy::HealthDriven));
        assert_eq!(RebuildPolicy::parse("health-driven"), Some(RebuildPolicy::HealthDriven));
        assert_eq!(RebuildPolicy::parse("sometimes"), None);
        assert_eq!(RebuildPolicy::default(), RebuildPolicy::Fixed);
        assert_eq!(RebuildPolicy::HealthDriven.name(), "health");
    }
}
