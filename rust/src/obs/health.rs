//! LSH table-health accounting: are the tables still any good?
//!
//! Selection quality drifts as weights move away from the tables that
//! indexed them (the SLIDE rebuild-cadence problem). This module gives
//! every `LayerTables`/`FrozenLayerTables` a [`HealthTally`] — per-node
//! activation counters folded in at selection time plus rebuild-age and
//! sampled-recall accumulators — and a [`TableHealth`] snapshot that
//! combines the tally with bucket-occupancy statistics read straight
//! from the tables.
//!
//! Everything here is relaxed atomics on the write path and pure reads
//! on the probe path, so enabling it cannot perturb model output (the
//! bitwise test in `tests/telemetry.rs` pins that).

use crate::nn::layer::Layer;
use crate::obs::export::{label, MetricKind};
use crate::tensor::vecops::{dot, top_k_indices};
use crate::util::json::JsonObject;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Per-table mutable health counters. Lives inside the table structs;
/// all writes are relaxed atomics so shared (`Arc`) frozen tables can
/// tally from many serve workers concurrently.
#[derive(Debug)]
pub struct HealthTally {
    /// Per-node selection counts ("running activations").
    counts: Vec<AtomicU64>,
    /// Ids whose count went 0 → 1, in first-activation order. Lets
    /// [`TableHealth::compute`] cost O(active) instead of O(nodes) on
    /// million-node layers; the lock is only taken when a node activates
    /// for the first time, so the steady-state fold-in stays lock-free.
    active: Mutex<Vec<u32>>,
    /// Running maximum over `counts` (updated via `fetch_max`).
    max_count: AtomicU64,
    /// Total node selections folded in (sum over counts).
    selections: AtomicU64,
    /// Micro-batches folded in since creation.
    batches: AtomicU64,
    /// Micro-batches folded in since the last rebuild.
    since_rebuild: AtomicU64,
    /// Sampled-recall accumulators: candidates checked / found in the
    /// dense top-k.
    recall_possible: AtomicU64,
    recall_hits: AtomicU64,
    recall_trials: AtomicU64,
}

impl HealthTally {
    pub fn new(n_nodes: usize) -> Self {
        let mut counts = Vec::with_capacity(n_nodes);
        counts.resize_with(n_nodes, || AtomicU64::new(0));
        HealthTally {
            counts,
            active: Mutex::new(Vec::new()),
            max_count: AtomicU64::new(0),
            selections: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            since_rebuild: AtomicU64::new(0),
            recall_possible: AtomicU64::new(0),
            recall_hits: AtomicU64::new(0),
            recall_trials: AtomicU64::new(0),
        }
    }

    /// Fold one micro-batch of per-sample selections in. `outs` holds
    /// the selected node ids per sample, exactly as `select_batch_into`
    /// produced them.
    pub fn note_batch(&self, outs: &[Vec<u32>]) {
        let mut total = 0u64;
        for sel in outs {
            for &id in sel {
                if let Some(c) = self.counts.get(id as usize) {
                    let prev = c.fetch_add(1, Ordering::Relaxed);
                    if prev == 0 {
                        // First activation of this node: remember it so
                        // snapshots never have to scan the full id space.
                        // (fetch_add returns 0 to exactly one caller.)
                        self.active.lock().expect("health lock").push(id);
                    }
                    self.max_count.fetch_max(prev + 1, Ordering::Relaxed);
                    total += 1;
                }
            }
        }
        self.selections.fetch_add(total, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.since_rebuild.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one sampled-recall trial in (`hits` of `possible` selected
    /// ids appeared in the dense top-k).
    pub fn note_recall(&self, hits: u64, possible: u64) {
        self.recall_hits.fetch_add(hits, Ordering::Relaxed);
        self.recall_possible.fetch_add(possible, Ordering::Relaxed);
        self.recall_trials.fetch_add(1, Ordering::Relaxed);
    }

    /// Called on table rebuild: the activation counters keep running,
    /// but the staleness clock restarts.
    pub fn reset_rebuild_age(&self) {
        self.since_rebuild.store(0, Ordering::Relaxed);
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn selections(&self) -> u64 {
        self.selections.load(Ordering::Relaxed)
    }

    pub fn node_count(&self, id: usize) -> u64 {
        self.counts[id].load(Ordering::Relaxed)
    }

    pub fn n_nodes(&self) -> usize {
        self.counts.len()
    }
}

/// A computed health snapshot for one layer's tables — the thing that
/// lands in BENCH_train_serve.json per epoch and in the exporter.
#[derive(Clone, Debug, Default)]
pub struct TableHealth {
    pub nodes: usize,
    pub tables: usize,
    /// Largest single bucket across all tables.
    pub max_bucket: usize,
    /// Mean size of *occupied* buckets.
    pub mean_occupied_bucket: f64,
    /// Fraction of buckets (over all tables) holding zero nodes.
    pub empty_bucket_fraction: f64,
    /// max_bucket / mean_occupied_bucket — 1.0 is perfectly even.
    pub occupancy_skew: f64,
    pub rebuilds: u64,
    /// Micro-batches since the last rebuild (staleness clock).
    pub rebuild_age_batches: u64,
    pub selection_batches: u64,
    pub selections: u64,
    /// Nodes selected at least once since creation.
    pub active_nodes: usize,
    pub never_active_fraction: f64,
    pub max_node_activations: u64,
    pub mean_node_activations: f64,
    /// Sampled overlap between LSH-selected ids and the dense top-k by
    /// activation; meaningless (0.0) when `recall_trials == 0`.
    pub recall_estimate: f64,
    pub recall_trials: u64,
}

impl TableHealth {
    /// Combine live bucket sizes (one `Vec<usize>` per table, empty
    /// buckets included) with the running tally. Cost is
    /// O(active + buckets), never O(nodes): the per-node scan was the one
    /// thing here that grew with layer width, and million-node layers make
    /// it unaffordable at telemetry cadence. The tally's first-activation
    /// list and running max replace it exactly.
    pub fn compute(bucket_sizes: &[Vec<usize>], rebuilds: u64, tally: &HealthTally) -> Self {
        let nodes = tally.n_nodes();
        let active_nodes = tally.active.lock().expect("health lock").len();
        let max_act = tally.max_count.load(Ordering::Relaxed);
        // `selections` counts exactly the ids folded into `counts`, so the
        // running total is the sum over counts without reading any of them.
        let act_sum = tally.selections();
        Self::assemble(
            bucket_sizes,
            rebuilds,
            tally,
            nodes,
            active_nodes,
            max_act,
            act_sum,
        )
    }

    /// Per-shard health row: node statistics restricted to the global-id
    /// `range` a shard owns, bucket statistics from that shard's own
    /// tables. Cost is O(active + shard buckets) — the first-activation
    /// list is filtered by range, never the count array scanned.
    pub fn compute_subset(
        bucket_sizes: &[Vec<usize>],
        rebuilds: u64,
        tally: &HealthTally,
        range: std::ops::Range<usize>,
    ) -> Self {
        let nodes = range.len();
        let mut active_nodes = 0usize;
        let mut max_act = 0u64;
        let mut act_sum = 0u64;
        for &id in tally.active.lock().expect("health lock").iter() {
            if range.contains(&(id as usize)) {
                let v = tally.counts[id as usize].load(Ordering::Relaxed);
                active_nodes += 1;
                max_act = max_act.max(v);
                act_sum += v;
            }
        }
        Self::assemble(bucket_sizes, rebuilds, tally, nodes, active_nodes, max_act, act_sum)
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        bucket_sizes: &[Vec<usize>],
        rebuilds: u64,
        tally: &HealthTally,
        nodes: usize,
        active_nodes: usize,
        max_act: u64,
        act_sum: u64,
    ) -> Self {
        let mut max_bucket = 0usize;
        let mut occupied = 0usize;
        let mut occupied_sum = 0usize;
        let mut total_buckets = 0usize;
        for table in bucket_sizes {
            total_buckets += table.len();
            for &sz in table {
                if sz > 0 {
                    occupied += 1;
                    occupied_sum += sz;
                    max_bucket = max_bucket.max(sz);
                }
            }
        }
        let mean_occupied_bucket =
            if occupied > 0 { occupied_sum as f64 / occupied as f64 } else { 0.0 };
        let empty_bucket_fraction = if total_buckets > 0 {
            (total_buckets - occupied) as f64 / total_buckets as f64
        } else {
            0.0
        };
        let occupancy_skew =
            if mean_occupied_bucket > 0.0 { max_bucket as f64 / mean_occupied_bucket } else { 0.0 };

        let never_active_fraction =
            if nodes > 0 { (nodes - active_nodes) as f64 / nodes as f64 } else { 0.0 };
        let mean_node_activations = if nodes > 0 { act_sum as f64 / nodes as f64 } else { 0.0 };

        let possible = tally.recall_possible.load(Ordering::Relaxed);
        let hits = tally.recall_hits.load(Ordering::Relaxed);
        let recall_estimate = if possible > 0 { hits as f64 / possible as f64 } else { 0.0 };

        TableHealth {
            nodes,
            tables: bucket_sizes.len(),
            max_bucket,
            mean_occupied_bucket,
            empty_bucket_fraction,
            occupancy_skew,
            rebuilds,
            rebuild_age_batches: tally.since_rebuild.load(Ordering::Relaxed),
            selection_batches: tally.batches(),
            selections: act_sum,
            active_nodes,
            never_active_fraction,
            max_node_activations: max_act,
            mean_node_activations,
            recall_estimate,
            recall_trials: tally.recall_trials.load(Ordering::Relaxed),
        }
    }

    pub fn to_json(&self) -> String {
        let mut o = JsonObject::new();
        o.usize("nodes", self.nodes)
            .usize("tables", self.tables)
            .usize("max_bucket", self.max_bucket)
            .fixed("mean_occupied_bucket", self.mean_occupied_bucket, 2)
            .fixed("empty_bucket_fraction", self.empty_bucket_fraction, 4)
            .fixed("occupancy_skew", self.occupancy_skew, 2)
            .u64("rebuilds", self.rebuilds)
            .u64("rebuild_age_batches", self.rebuild_age_batches)
            .u64("selection_batches", self.selection_batches)
            .u64("selections", self.selections)
            .usize("active_nodes", self.active_nodes)
            .fixed("never_active_fraction", self.never_active_fraction, 4)
            .u64("max_node_activations", self.max_node_activations)
            .fixed("mean_node_activations", self.mean_node_activations, 2)
            .fixed("recall_estimate", self.recall_estimate, 4)
            .u64("recall_trials", self.recall_trials);
        o.finish()
    }
}

/// Dense-score every node of `layer` against query `q` and tally how
/// many of the LSH-`selected` ids land in the true top-|selected| by
/// activation. Pure reads — runs on a sampled batch, never touches the
/// forward path.
pub fn recall_probe(layer: &Layer, q: &[f32], selected: &[u32], tally: &HealthTally) {
    let k = selected.len();
    let n_out = layer.n_out();
    if k == 0 || n_out == 0 || layer.n_in() != q.len() {
        return;
    }
    let z: Vec<f32> = (0..n_out).map(|i| dot(layer.w.row(i), q) + layer.b[i]).collect();
    let top = top_k_indices(&z, k);
    let mut mark = vec![false; n_out];
    for id in top {
        mark[id as usize] = true;
    }
    let hits = selected.iter().filter(|&&id| (id as usize) < n_out && mark[id as usize]).count();
    tally.note_recall(hits as u64, k as u64);
}

// --- exporter board ---------------------------------------------------

/// Latest health row per (layer, shard). The trainer's selectors are
/// mutably borrowed while training runs, so the exporter cannot hold
/// reader closures into them; instead each epoch *pushes* its rows here
/// and the registered gauges read the board.
fn board() -> &'static Mutex<BTreeMap<(usize, usize), TableHealth>> {
    static B: OnceLock<Mutex<BTreeMap<(usize, usize), TableHealth>>> = OnceLock::new();
    B.get_or_init(|| Mutex::new(BTreeMap::new()))
}

fn board_read(layer: usize, shard: usize, f: fn(&TableHealth) -> f64) -> f64 {
    board().lock().expect("health board").get(&(layer, shard)).map(f).unwrap_or(0.0)
}

/// Publish one layer's (or one shard's) health row to the global
/// exporter: the first push of a given (layer, shard) registers a
/// labeled series per health family — `layer="l"` alone when the layer
/// is unsharded, `layer="l",shard="s"` when sharded — and every push
/// updates the value the gauges read. Pure bookkeeping: no RNG, nothing
/// reads the board on a model path.
pub fn publish_health_row(layer: usize, shard: usize, sharded: bool, h: &TableHealth) {
    board().lock().expect("health board").insert((layer, shard), h.clone());

    static REGISTERED: OnceLock<Mutex<Vec<(usize, usize, bool)>>> = OnceLock::new();
    let reg = REGISTERED.get_or_init(|| Mutex::new(Vec::new()));
    {
        let mut g = reg.lock().expect("health board registry");
        if g.contains(&(layer, shard, sharded)) {
            return;
        }
        g.push((layer, shard, sharded));
    }
    let labels = if sharded {
        format!("{},{}", label("layer", &layer.to_string()), label("shard", &shard.to_string()))
    } else {
        label("layer", &layer.to_string())
    };
    type Field = (&'static str, MetricKind, fn(&TableHealth) -> f64);
    const FIELDS: [Field; 8] = [
        ("hashdl_table_nodes", MetricKind::Gauge, |h| h.nodes as f64),
        ("hashdl_table_max_bucket", MetricKind::Gauge, |h| h.max_bucket as f64),
        ("hashdl_table_empty_bucket_fraction", MetricKind::Gauge, |h| h.empty_bucket_fraction),
        ("hashdl_table_occupancy_skew", MetricKind::Gauge, |h| h.occupancy_skew),
        ("hashdl_table_recall_estimate", MetricKind::Gauge, |h| h.recall_estimate),
        ("hashdl_table_recall_trials_total", MetricKind::Counter, |h| h.recall_trials as f64),
        ("hashdl_table_rebuilds_total", MetricKind::Counter, |h| h.rebuilds as f64),
        ("hashdl_table_rebuild_age_batches", MetricKind::Gauge, |h| h.rebuild_age_batches as f64),
    ];
    for (name, kind, read) in FIELDS {
        crate::obs::export::global()
            .register_labeled_scalar(name, &labels, kind, move || board_read(layer, shard, read));
    }
}

// --- sampling cadence -------------------------------------------------

static RECALL_EVERY: AtomicU64 = AtomicU64::new(64);
static RECALL_TICK: AtomicU64 = AtomicU64::new(0);

/// Run the recall probe on every `n`th selection batch (0 disables;
/// default 64). The first eligible batch always probes, so even short
/// smoke runs produce at least one trial.
pub fn set_recall_every(n: u64) {
    RECALL_EVERY.store(n, Ordering::Relaxed);
}

/// Should this selection batch run the recall probe? Increments the
/// global tick.
pub fn recall_due() -> bool {
    let n = RECALL_EVERY.load(Ordering::Relaxed);
    if n == 0 {
        return false;
    }
    RECALL_TICK.fetch_add(1, Ordering::Relaxed) % n == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tally_counts_are_exact() {
        let t = HealthTally::new(4);
        t.note_batch(&[vec![0, 2], vec![2, 3]]);
        t.note_batch(&[vec![2]]);
        assert_eq!(t.node_count(0), 1);
        assert_eq!(t.node_count(1), 0);
        assert_eq!(t.node_count(2), 3);
        assert_eq!(t.node_count(3), 1);
        assert_eq!(t.selections(), 5);
        assert_eq!(t.batches(), 2);
    }

    #[test]
    fn compute_matches_a_full_scan_of_the_counts() {
        // The O(active) fast path must agree with what a per-node scan
        // would have reported.
        let t = HealthTally::new(6);
        t.note_batch(&[vec![0, 5, 5], vec![2, 5]]);
        let h = TableHealth::compute(&[vec![3, 3]], 0, &t);
        assert_eq!(h.nodes, 6);
        assert_eq!(h.active_nodes, 3);
        assert_eq!(h.max_node_activations, 3);
        assert_eq!(h.selections, 5);
        assert!((h.mean_node_activations - 5.0 / 6.0).abs() < 1e-12);
        assert!((h.never_active_fraction - 0.5).abs() < 1e-12);
    }

    #[test]
    fn compute_subset_restricts_to_the_id_range() {
        let t = HealthTally::new(8);
        t.note_batch(&[vec![0, 1, 5, 5, 7]]);
        // Shard owning ids [4, 8): nodes 5 (twice) and 7 (once) are active.
        let h = TableHealth::compute_subset(&[vec![2, 1]], 3, &t, 4..8);
        assert_eq!(h.nodes, 4);
        assert_eq!(h.active_nodes, 2);
        assert_eq!(h.selections, 3);
        assert_eq!(h.max_node_activations, 2);
        assert_eq!(h.rebuilds, 3);
        assert!((h.never_active_fraction - 0.5).abs() < 1e-12);
        // The other shard's row sees the complement.
        let lo = TableHealth::compute_subset(&[vec![2, 1]], 0, &t, 0..4);
        assert_eq!(lo.active_nodes, 2);
        assert_eq!(lo.selections, 2);
        assert_eq!(lo.max_node_activations, 1);
    }

    #[test]
    fn rebuild_resets_age_not_counts() {
        let t = HealthTally::new(2);
        t.note_batch(&[vec![0]]);
        t.reset_rebuild_age();
        t.note_batch(&[vec![1]]);
        let h = TableHealth::compute(&[vec![1, 1]], 1, &t);
        assert_eq!(h.rebuild_age_batches, 1);
        assert_eq!(h.selection_batches, 2);
        assert_eq!(h.rebuilds, 1);
    }

    #[test]
    fn occupancy_stats_on_hand_built_buckets() {
        // two tables of 4 buckets: sizes [3,0,1,0] and [0,0,2,2].
        let bs = vec![vec![3, 0, 1, 0], vec![0, 0, 2, 2]];
        let t = HealthTally::new(8);
        let h = TableHealth::compute(&bs, 0, &t);
        assert_eq!(h.tables, 2);
        assert_eq!(h.max_bucket, 3);
        assert!((h.mean_occupied_bucket - 2.0).abs() < 1e-12); // (3+1+2+2)/4
        assert!((h.empty_bucket_fraction - 0.5).abs() < 1e-12); // 4 of 8
        assert!((h.occupancy_skew - 1.5).abs() < 1e-12);
    }

    #[test]
    fn recall_accumulates_as_ratio() {
        let t = HealthTally::new(4);
        t.note_recall(1, 2);
        t.note_recall(2, 2);
        let h = TableHealth::compute(&[], 0, &t);
        assert_eq!(h.recall_trials, 2);
        assert!((h.recall_estimate - 0.75).abs() < 1e-12);
    }

    #[test]
    fn empty_everything_is_zero_not_nan() {
        let t = HealthTally::new(0);
        let h = TableHealth::compute(&[], 0, &t);
        assert_eq!(h.occupancy_skew, 0.0);
        assert_eq!(h.mean_node_activations, 0.0);
        assert_eq!(h.recall_estimate, 0.0);
        assert!(h.to_json().starts_with('{'));
    }

    #[test]
    fn health_board_exports_labeled_rows() {
        // Use high layer indices so other tests' rows cannot collide.
        let mut h = TableHealth { occupancy_skew: 2.5, ..TableHealth::default() };
        publish_health_row(91, 0, false, &h);
        publish_health_row(92, 1, true, &h);
        let text = crate::obs::export::global().snapshot().to_prometheus();
        assert!(text.contains("hashdl_table_occupancy_skew{layer=\"91\"} 2.5"), "{text}");
        assert!(
            text.contains("hashdl_table_occupancy_skew{layer=\"92\",shard=\"1\"} 2.5"),
            "{text}"
        );
        // A later push updates the value behind the same series.
        h.occupancy_skew = 4.0;
        publish_health_row(91, 0, false, &h);
        let text = crate::obs::export::global().snapshot().to_prometheus();
        assert!(text.contains("hashdl_table_occupancy_skew{layer=\"91\"} 4"), "{text}");
    }

    #[test]
    fn recall_probe_perfect_on_identity_layer() {
        use crate::nn::activation::Activation;
        use crate::tensor::matrix::Matrix;
        // 3 nodes over 3 inputs, w = I, b = 0: activations == q.
        let mut w = Matrix::zeros(3, 3);
        for i in 0..3 {
            w.row_mut(i)[i] = 1.0;
        }
        let layer = Layer { w, b: vec![0.0; 3], act: Activation::ReLU };
        let t = HealthTally::new(3);
        // q favours node 2 then 0; selecting exactly those two is 100%.
        recall_probe(&layer, &[0.5, -1.0, 2.0], &[2, 0], &t);
        let h = TableHealth::compute(&[], 0, &t);
        assert_eq!(h.recall_trials, 1);
        assert!((h.recall_estimate - 1.0).abs() < 1e-12);
        // Selecting the worst node instead is 50%.
        recall_probe(&layer, &[0.5, -1.0, 2.0], &[2, 1], &t);
        let h = TableHealth::compute(&[], 0, &t);
        assert!((h.recall_estimate - 0.75).abs() < 1e-12);
    }
}
