//! Bounded process-global structured event journal.
//!
//! Metrics answer "how much"; the journal answers "what happened when".
//! Every discrete lifecycle action in the stack — a model publication, a
//! hash-table rebuild (full or per-shard), a shed request, a canary
//! divert, a drift alert — lands here as one [`Event`] with a
//! process-monotonic sequence number. The journal is a fixed-capacity
//! ring: old events fall off the front (counted, never silently), so a
//! long-running server keeps a bounded recent history that `/events`
//! and `--metrics-out` can export as JSONL.
//!
//! Same contract as the rest of `obs`: emitting draws no RNG and nothing
//! branches on journal state, so the observatory cannot perturb model
//! output (pinned by `tests/observatory.rs`). Emission respects the
//! master telemetry switch ([`crate::obs::enabled`]).

use crate::util::json::JsonObject;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// What kind of lifecycle action an event records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A model version entered the publication slot (`detail: "publish"`)
    /// or a serve worker re-pinned to it (`detail: "pickup"`).
    Publish,
    /// A full hash-table rebuild (`lsh/layered.rs`), or — with subject
    /// `"adaptive"` — a health-driven rebuild decision beyond the fixed
    /// cadence.
    Rebuild,
    /// One shard of a sharded layer rebuilt (staggered or forced).
    ShardRebuild,
    /// The router shed a request at a model's full bounded queue.
    Shed,
    /// The router diverted a request to the canary model.
    CanaryDecision,
    /// A drift detector tripped (see `obs::drift`).
    DriftAlert,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Publish => "publish",
            EventKind::Rebuild => "rebuild",
            EventKind::ShardRebuild => "shard_rebuild",
            EventKind::Shed => "shed",
            EventKind::CanaryDecision => "canary_decision",
            EventKind::DriftAlert => "drift_alert",
        }
    }
}

/// One journal entry. `seq` is process-monotonic (gaps only if the
/// journal itself is bypassed, which it never is); `t_micros` is
/// microseconds since process start.
#[derive(Clone, Debug)]
pub struct Event {
    pub seq: u64,
    pub t_micros: u64,
    pub kind: EventKind,
    /// What the event is about: a model name, `"publisher"`, `"shard"`,
    /// a drift metric name, …
    pub subject: String,
    /// Primary numeric payload: version, shard index, cumulative count —
    /// whatever the kind's docs say.
    pub value: u64,
    /// Free-form qualifier (`"publish"` vs `"pickup"`, a drift reason, …).
    pub detail: String,
}

impl Event {
    pub fn to_json(&self) -> String {
        let mut o = JsonObject::new();
        o.u64("seq", self.seq)
            .u64("t_micros", self.t_micros)
            .str("kind", self.kind.name())
            .str("subject", &self.subject)
            .u64("value", self.value)
            .str("detail", &self.detail);
        o.finish()
    }
}

/// Default capacity of the process-global journal.
pub const DEFAULT_JOURNAL_CAP: usize = 4096;

/// A bounded event ring. `emit` is a short Mutex push (events are rare
/// next to requests); `recent` snapshots the tail without blocking
/// writers for long.
pub struct EventJournal {
    cap: usize,
    seq: AtomicU64,
    dropped: AtomicU64,
    ring: Mutex<VecDeque<Event>>,
}

impl EventJournal {
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(1);
        EventJournal {
            cap,
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::with_capacity(cap)),
        }
    }

    /// Append one event; returns its sequence number. The oldest event
    /// falls off (and is counted in `dropped`) when the ring is full.
    pub fn emit(&self, kind: EventKind, subject: &str, value: u64, detail: &str) -> u64 {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let ev = Event {
            seq,
            t_micros: super::uptime_micros(),
            kind,
            subject: subject.to_string(),
            value,
            detail: detail.to_string(),
        };
        let mut g = self.ring.lock().expect("journal poisoned");
        if g.len() == self.cap {
            g.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        g.push_back(ev);
        seq
    }

    /// Total events ever emitted (monotone, survives ring eviction).
    pub fn total(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Events evicted from the ring so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Events currently held.
    pub fn len(&self) -> usize {
        self.ring.lock().expect("journal poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The newest `n` events in chronological (seq-ascending) order.
    pub fn recent(&self, n: usize) -> Vec<Event> {
        let g = self.ring.lock().expect("journal poisoned");
        let skip = g.len().saturating_sub(n);
        g.iter().skip(skip).cloned().collect()
    }

    /// The newest `n` events as JSONL (one JSON object per line, newline
    /// terminated; empty string when the journal is empty).
    pub fn to_jsonl(&self, n: usize) -> String {
        let mut out = String::new();
        for ev in self.recent(n) {
            out.push_str(&ev.to_json());
            out.push('\n');
        }
        out
    }
}

/// The process-global journal. First call registers the journal's own
/// counters into the global metrics registry.
pub fn journal() -> &'static EventJournal {
    static J: OnceLock<EventJournal> = OnceLock::new();
    static REG: OnceLock<()> = OnceLock::new();
    let j: &'static EventJournal = J.get_or_init(|| EventJournal::with_capacity(DEFAULT_JOURNAL_CAP));
    REG.get_or_init(|| {
        super::export::global()
            .register_counter("hashdl_events_total", || journal().total() as f64);
        super::export::global()
            .register_counter("hashdl_events_dropped_total", || journal().dropped() as f64);
    });
    j
}

/// Emit into the global journal, honoring the master telemetry switch
/// (`--telemetry off` silences the journal exactly like the stage
/// histograms).
#[inline]
pub fn emit(kind: EventKind, subject: &str, value: u64, detail: &str) {
    if super::enabled() {
        journal().emit(kind, subject, value, detail);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_keeps_the_newest() {
        let j = EventJournal::with_capacity(4);
        for i in 0..10u64 {
            j.emit(EventKind::Rebuild, "t", i, "");
        }
        assert_eq!(j.len(), 4);
        assert_eq!(j.total(), 10);
        assert_eq!(j.dropped(), 6);
        let tail = j.recent(100);
        let seqs: Vec<u64> = tail.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "oldest evicted first, order kept");
    }

    #[test]
    fn recent_n_takes_the_tail() {
        let j = EventJournal::with_capacity(8);
        for i in 0..5u64 {
            j.emit(EventKind::Publish, "p", i, "publish");
        }
        let two = j.recent(2);
        assert_eq!(two.len(), 2);
        assert_eq!(two[0].seq, 3);
        assert_eq!(two[1].seq, 4);
    }

    #[test]
    fn jsonl_is_one_object_per_line() {
        let j = EventJournal::with_capacity(8);
        j.emit(EventKind::Shed, "m\"0", 1, "");
        j.emit(EventKind::DriftAlert, "recall", 2, "0.80 -> 0.55");
        let text = j.to_jsonl(10);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            assert!(line.starts_with('{') && line.ends_with('}'), "bad line: {line}");
        }
        assert!(lines[0].contains("\"kind\": \"shed\""));
        assert!(lines[0].contains("m\\\"0"), "subjects must be escaped");
        assert!(lines[1].contains("\"kind\": \"drift_alert\""));
    }

    #[test]
    fn global_journal_registers_its_counters() {
        journal();
        let names = super::super::export::global().snapshot().names();
        assert!(names.contains(&"hashdl_events_total".to_string()));
        assert!(names.contains(&"hashdl_events_dropped_total".to_string()));
    }
}
