//! Fixed-capacity ring time-series over the metrics registry.
//!
//! A [`SeriesRing`] holds the last N samples of one metric as
//! `(t_micros, value)` pairs in preallocated atomic slots — the writer
//! (one background sampler thread) publishes each sample with two
//! relaxed stores and a release bump of the head counter; readers never
//! block it. A [`SeriesStore`] keys one ring per registry metric
//! (scalars verbatim, histograms as their `_count`), and
//! [`SeriesRollup`] summarises a ring's window: last/min/max/mean, plus
//! a counter's delta-over-time as a rate.
//!
//! The global store is fed by [`ensure_sampler`] — a daemon thread that
//! snapshots [`crate::obs::global`] on a fixed interval and hands the
//! fresh window to the drift scanner (`obs::drift`). Rollups land in the
//! `--metrics-out` JSON twin and back the `/metrics.json` endpoint's
//! history.

use crate::obs::export::{MetricKind, MetricsSnapshot};
use crate::util::json::{JsonArray, JsonObject};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// One sample of one series.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SeriesPoint {
    pub t_micros: u64,
    pub value: f64,
}

struct Slot {
    t: AtomicU64,
    bits: AtomicU64,
}

/// Lock-free fixed-capacity ring of samples. Single writer (the sampler
/// thread), any number of readers: `push` stores the slot then bumps
/// `head` with release ordering; `window` reads `head` before and after
/// copying and discards any slots the writer lapped in between, so a
/// snapshot is always a consistent suffix of the series.
pub struct SeriesRing {
    slots: Box<[Slot]>,
    /// Total samples ever pushed (ring index = head % capacity).
    head: AtomicU64,
}

impl SeriesRing {
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(2);
        let mut slots = Vec::with_capacity(cap);
        slots.resize_with(cap, || Slot { t: AtomicU64::new(0), bits: AtomicU64::new(0) });
        SeriesRing { slots: slots.into_boxed_slice(), head: AtomicU64::new(0) }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total samples ever pushed (not capped by capacity).
    pub fn total(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Append one sample. Single-writer: callers must serialise pushes
    /// (the global store's sampler thread is the only writer in
    /// practice).
    pub fn push(&self, t_micros: u64, value: f64) {
        let head = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(head % self.slots.len() as u64) as usize];
        slot.t.store(t_micros, Ordering::Relaxed);
        slot.bits.store(value.to_bits(), Ordering::Relaxed);
        self.head.store(head + 1, Ordering::Release);
    }

    /// The retained window, oldest → newest. Samples overwritten while
    /// the copy was in flight are dropped from the front.
    pub fn window(&self) -> Vec<SeriesPoint> {
        let cap = self.slots.len() as u64;
        let before = self.head.load(Ordering::Acquire);
        let held = before.min(cap);
        let start = before - held;
        let mut out = Vec::with_capacity(held as usize);
        for i in start..before {
            let slot = &self.slots[(i % cap) as usize];
            out.push(SeriesPoint {
                t_micros: slot.t.load(Ordering::Relaxed),
                value: f64::from_bits(slot.bits.load(Ordering::Relaxed)),
            });
        }
        let after = self.head.load(Ordering::Acquire);
        // The writer advanced by (after - before) during the copy; that
        // many of the oldest copied slots may hold torn/new data.
        let lapped = (after - before).min(out.len() as u64) as usize;
        out.drain(..lapped);
        out
    }
}

/// Windowed summary of one series.
#[derive(Clone, Debug)]
pub struct SeriesRollup {
    pub name: String,
    pub kind: MetricKind,
    /// Samples in the summarised window.
    pub samples: usize,
    pub last: f64,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    /// Counters only: (last − first) / window seconds. 0 for gauges or
    /// windows under two samples.
    pub rate_per_sec: f64,
}

impl SeriesRollup {
    /// Summarise a window (as produced by [`SeriesRing::window`]).
    pub fn of(name: &str, kind: MetricKind, window: &[SeriesPoint]) -> Option<Self> {
        let first = window.first()?;
        let last = window.last()?;
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut sum = 0.0;
        for p in window {
            min = min.min(p.value);
            max = max.max(p.value);
            sum += p.value;
        }
        let span_secs = (last.t_micros.saturating_sub(first.t_micros)) as f64 / 1e6;
        let rate_per_sec = if kind == MetricKind::Counter && window.len() >= 2 && span_secs > 0.0
        {
            (last.value - first.value) / span_secs
        } else {
            0.0
        };
        Some(SeriesRollup {
            name: name.to_string(),
            kind,
            samples: window.len(),
            last: last.value,
            min,
            max,
            mean: sum / window.len() as f64,
            rate_per_sec,
        })
    }

    pub fn to_json(&self) -> String {
        let mut o = JsonObject::new();
        o.str("name", &self.name)
            .str("kind", match self.kind {
                MetricKind::Counter => "counter",
                MetricKind::Gauge => "gauge",
            })
            .usize("samples", self.samples)
            .f64("last", self.last)
            .f64("min", self.min)
            .f64("max", self.max)
            .fixed("mean", self.mean, 3)
            .fixed("rate_per_sec", self.rate_per_sec, 3);
        o.finish()
    }
}

/// Name → ring map over a metrics registry. Rings are created on first
/// sight of a metric and shared out as `Arc` so drift detectors can hold
/// one without locking the store.
pub struct SeriesStore {
    cap: usize,
    series: Mutex<Vec<(String, MetricKind, Arc<SeriesRing>)>>,
}

impl SeriesStore {
    pub fn with_capacity(cap: usize) -> Self {
        SeriesStore { cap, series: Mutex::new(Vec::new()) }
    }

    /// Fold one registry snapshot in at time `t_micros`: every scalar
    /// becomes a sample under its qualified name (`name` or
    /// `name{labels}`), every histogram contributes its cumulative
    /// `_count` as a counter series.
    pub fn sample(&self, snap: &MetricsSnapshot, t_micros: u64) {
        let mut g = self.series.lock().expect("series store poisoned");
        for (name, labels, kind, v) in &snap.scalars {
            let key = qualified(name, labels);
            Self::push_locked(&mut g, self.cap, &key, *kind, t_micros, *v);
        }
        for (name, snap_h) in &snap.hists {
            let key = format!("{name}_count");
            Self::push_locked(&mut g, self.cap, &key, MetricKind::Counter, t_micros, snap_h.count() as f64);
        }
    }

    fn push_locked(
        g: &mut Vec<(String, MetricKind, Arc<SeriesRing>)>,
        cap: usize,
        key: &str,
        kind: MetricKind,
        t_micros: u64,
        v: f64,
    ) {
        if let Some((_, _, ring)) = g.iter().find(|(n, _, _)| n == key) {
            ring.push(t_micros, v);
        } else {
            let ring = Arc::new(SeriesRing::with_capacity(cap));
            ring.push(t_micros, v);
            g.push((key.to_string(), kind, ring));
        }
    }

    /// The ring for a qualified metric name, if it has ever been sampled.
    pub fn get(&self, key: &str) -> Option<Arc<SeriesRing>> {
        self.series
            .lock()
            .expect("series store poisoned")
            .iter()
            .find(|(n, _, _)| n == key)
            .map(|(_, _, r)| Arc::clone(r))
    }

    /// All (key, kind, ring) triples, in first-seen order.
    pub fn all(&self) -> Vec<(String, MetricKind, Arc<SeriesRing>)> {
        self.series
            .lock()
            .expect("series store poisoned")
            .iter()
            .map(|(n, k, r)| (n.clone(), *k, Arc::clone(r)))
            .collect()
    }

    /// Roll every series' retained window up.
    pub fn rollups(&self) -> Vec<SeriesRollup> {
        self.all()
            .into_iter()
            .filter_map(|(n, k, r)| SeriesRollup::of(&n, k, &r.window()))
            .collect()
    }

    /// Rollups as a JSON array (the `--metrics-out` twin's `series`
    /// field).
    pub fn rollups_to_json(&self) -> String {
        let mut arr = JsonArray::new();
        for r in self.rollups() {
            arr.push_raw(&r.to_json());
        }
        arr.finish()
    }
}

fn qualified(name: &str, labels: &str) -> String {
    if labels.is_empty() {
        name.to_string()
    } else {
        format!("{name}{{{labels}}}")
    }
}

/// Ring capacity of the global store: at the default 250 ms sampling
/// interval this retains ~2 minutes of history per metric.
pub const GLOBAL_SERIES_CAP: usize = 512;

/// The process-global series store (fed by [`ensure_sampler`] or
/// explicit [`sample_global_now`] calls).
pub fn store() -> &'static SeriesStore {
    static S: OnceLock<SeriesStore> = OnceLock::new();
    S.get_or_init(|| SeriesStore::with_capacity(GLOBAL_SERIES_CAP))
}

/// Take one sample of the global registry into the global store right
/// now (the sampler does this on its interval; `--metrics-out` does it
/// once more at exit so rollups include the final state).
pub fn sample_global_now() {
    store().sample(&crate::obs::global().snapshot(), crate::obs::uptime_micros());
}

/// Start the background sampler thread (idempotent — the first caller's
/// interval wins). Each tick snapshots the global registry into the
/// global store and lets the drift scanner look at the fresh window.
pub fn ensure_sampler(interval: Duration) {
    static STARTED: OnceLock<()> = OnceLock::new();
    STARTED.get_or_init(|| {
        std::thread::Builder::new()
            .name("hashdl-obs-sampler".into())
            .spawn(move || loop {
                if crate::obs::enabled() {
                    sample_global_now();
                    crate::obs::drift::scan_global_series();
                }
                std::thread::sleep(interval);
            })
            .expect("spawn obs sampler");
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_a_suffix_in_order() {
        let r = SeriesRing::with_capacity(4);
        for i in 0..7u64 {
            r.push(i * 10, i as f64);
        }
        let w = r.window();
        assert_eq!(w.len(), 4);
        assert_eq!(w[0], SeriesPoint { t_micros: 30, value: 3.0 });
        assert_eq!(w[3], SeriesPoint { t_micros: 60, value: 6.0 });
        assert_eq!(r.total(), 7);
    }

    #[test]
    fn short_ring_window_is_everything_so_far() {
        let r = SeriesRing::with_capacity(8);
        r.push(5, 1.5);
        let w = r.window();
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].value, 1.5);
    }

    #[test]
    fn rollup_summarises_and_rates_counters() {
        let pts: Vec<SeriesPoint> = (0..5)
            .map(|i| SeriesPoint { t_micros: i * 1_000_000, value: (i * 100) as f64 })
            .collect();
        let c = SeriesRollup::of("reqs_total", MetricKind::Counter, &pts).unwrap();
        assert_eq!(c.samples, 5);
        assert_eq!(c.last, 400.0);
        assert_eq!(c.min, 0.0);
        assert_eq!(c.max, 400.0);
        assert!((c.mean - 200.0).abs() < 1e-9);
        // 400 over 4 seconds.
        assert!((c.rate_per_sec - 100.0).abs() < 1e-9, "rate {}", c.rate_per_sec);
        let g = SeriesRollup::of("queue_len", MetricKind::Gauge, &pts).unwrap();
        assert_eq!(g.rate_per_sec, 0.0, "gauges do not rate");
        assert!(SeriesRollup::of("empty", MetricKind::Gauge, &[]).is_none());
    }

    #[test]
    fn store_samples_scalars_and_hist_counts() {
        use crate::serve::stats::LatencyHistogram;
        let reg = crate::obs::export::MetricsRegistry::new();
        reg.register_counter("s_total", || 7.0);
        reg.register_labeled_gauge("s_gauge", "layer=\"0\"", || 0.25);
        let h = LatencyHistogram::new();
        h.record(10);
        let hs = h.snapshot();
        reg.register_histogram("s_lat_micros", move || hs.clone());
        let store = SeriesStore::with_capacity(16);
        store.sample(&reg.snapshot(), 1_000);
        store.sample(&reg.snapshot(), 2_000);
        let names: Vec<String> = store.all().iter().map(|(n, _, _)| n.clone()).collect();
        assert!(names.contains(&"s_total".to_string()));
        assert!(names.contains(&"s_gauge{layer=\"0\"}".to_string()));
        assert!(names.contains(&"s_lat_micros_count".to_string()));
        let ring = store.get("s_total").unwrap();
        assert_eq!(ring.window().len(), 2);
        let rollups = store.rollups();
        assert_eq!(rollups.len(), 3);
        let js = store.rollups_to_json();
        assert!(js.starts_with('[') && js.ends_with(']'));
        assert!(js.contains("\"name\": \"s_total\""));
    }
}
