//! Paper-style result tables: fixed-width console rendering + CSV export.

use std::fmt::Write as _;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Report {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Report {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    /// Fixed-width table rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(s, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(s, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(s, "{}", line(row, &widths));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.headers.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }

    /// Print to stdout and optionally write CSV next to `out_dir`.
    pub fn emit(&self, out_dir: Option<&Path>) {
        print!("{}", self.render());
        println!();
        if let Some(dir) = out_dir {
            std::fs::create_dir_all(dir).ok();
            let file = dir.join(format!(
                "{}.csv",
                self.title.to_ascii_lowercase().replace([' ', '/', ':'], "_")
            ));
            if let Err(e) = std::fs::write(&file, self.to_csv()) {
                eprintln!("warning: could not write {}: {e}", file.display());
            } else {
                eprintln!("wrote {}", file.display());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        let mut r = Report::new("Fig X", &["dataset", "acc"]);
        r.row(vec!["mnist".into(), "0.97".into()]);
        r.row(vec!["norb-longer".into(), "0.9".into()]);
        r
    }

    #[test]
    fn render_aligns_columns() {
        let out = sample().render();
        assert!(out.contains("== Fig X =="));
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 5);
        // all data lines same width
        assert_eq!(lines[2].len(), lines[3].len().max(lines[2].len()));
    }

    #[test]
    fn csv_format() {
        let csv = sample().to_csv();
        assert_eq!(csv.lines().next().unwrap(), "dataset,acc");
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn emit_writes_csv() {
        let dir = std::env::temp_dir().join("hashdl_report_test");
        sample().emit(Some(&dir));
        let content = std::fs::read_to_string(dir.join("fig_x.csv")).unwrap();
        assert!(content.contains("mnist,0.97"));
        std::fs::remove_dir_all(dir).ok();
    }
}
