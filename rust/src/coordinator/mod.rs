//! The experiment coordinator: figure/table drivers and report rendering.
//! `main.rs` dispatches CLI subcommands here; examples/benches call the
//! same entry points so every number in EXPERIMENTS.md is regenerable.

pub mod experiment;
pub mod report;

pub use experiment::{ExperimentScale, SPARSITY_GRID};
pub use report::Report;
