//! Per-figure experiment drivers. Each function regenerates one table or
//! figure of the paper (scaled to this testbed by `ExperimentScale`; the
//! shapes — who wins, where the crossovers fall — are the reproduction
//! target, not absolute numbers).

use crate::coordinator::report::Report;
use crate::data::synth::Benchmark;
use crate::nn::activation::Activation;
use crate::nn::network::{Network, NetworkConfig};
use crate::optim::OptimConfig;
use crate::sampling::{Method, SamplerConfig};
use crate::train::asgd::{run_asgd, AsgdConfig};
use crate::train::trainer::{TrainConfig, Trainer};
use crate::util::rng::Pcg64;

/// The paper's active-node grid (x-axis of Figs 4/5).
pub const SPARSITY_GRID: [f32; 6] = [0.05, 0.10, 0.25, 0.50, 0.75, 0.90];

/// Scaling knobs: defaults give minutes-scale runs; `--scale paper`
/// approaches the paper's sizes (hours).
#[derive(Clone, Copy, Debug)]
pub struct ExperimentScale {
    pub hidden: usize,
    pub train_frac: f32,
    pub test_cap: usize,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
}

impl ExperimentScale {
    pub fn quick() -> Self {
        ExperimentScale { hidden: 128, train_frac: 0.15, test_cap: 500, epochs: 4, lr: 1e-2, seed: 42 }
    }

    pub fn medium() -> Self {
        ExperimentScale { hidden: 400, train_frac: 0.5, test_cap: 1000, epochs: 8, lr: 1e-2, seed: 42 }
    }

    /// Paper architecture (1000-node hidden layers, full default sizes).
    pub fn paper() -> Self {
        ExperimentScale { hidden: 1000, train_frac: 1.0, test_cap: 2000, epochs: 10, lr: 1e-2, seed: 42 }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "quick" => Ok(Self::quick()),
            "medium" => Ok(Self::medium()),
            "paper" => Ok(Self::paper()),
            other => Err(format!("unknown scale {other:?} (quick|medium|paper)")),
        }
    }
}

fn sizes(b: Benchmark, s: &ExperimentScale) -> (usize, usize) {
    let (tr, te) = b.default_sizes();
    (((tr as f32 * s.train_frac) as usize).max(200), te.min(s.test_cap.max(100)))
}

fn network(b: Benchmark, depth: usize, s: &ExperimentScale, seed: u64) -> Network {
    Network::new(
        &NetworkConfig {
            n_in: b.dim(),
            hidden: vec![s.hidden; depth],
            n_out: b.n_classes(),
            act: Activation::ReLU,
        },
        &mut Pcg64::seeded(seed),
    )
}

fn sampler_for(method: Method, sparsity: f32) -> SamplerConfig {
    if method == Method::Lsh {
        return SamplerConfig::lsh_tuned(sparsity);
    }
    let mut sc = SamplerConfig::with_method(method, sparsity);
    if method == Method::AdaptiveDropout {
        sc.ad_beta = crate::sampling::adaptive::AdaptiveDropoutSelector::beta_for_sparsity(sparsity);
    }
    sc
}

/// Table/Fig 3: dataset inventory.
pub fn table3() -> Report {
    let mut r = Report::new(
        "Table 3: datasets",
        &["dataset", "paper_train", "paper_test", "default_train", "default_test", "dim", "classes"],
    );
    for b in Benchmark::all() {
        let (pt, pe) = b.paper_sizes();
        let (dt, de) = b.default_sizes();
        r.row(vec![
            b.name().into(),
            pt.to_string(),
            pe.to_string(),
            dt.to_string(),
            de.to_string(),
            b.dim().to_string(),
            b.n_classes().to_string(),
        ]);
    }
    r
}

/// Figs 4/5: accuracy vs %active for the chosen methods and depths.
/// AD is skipped below 25% active — the paper reports divergence there
/// (Fig 5 caption) and we mark it "div".
pub fn fig45(
    datasets: &[Benchmark],
    methods: &[Method],
    depths: &[usize],
    grid: &[f32],
    s: &ExperimentScale,
    verbose: bool,
) -> Report {
    let mut r = Report::new(
        "Figs 4-5: accuracy vs active-node fraction",
        &["dataset", "depth", "method", "sparsity", "test_acc", "mult_ratio"],
    );
    for &b in datasets {
        let (n_tr, n_te) = sizes(b, s);
        let (train, test) = b.generate(n_tr, n_te, s.seed);
        for &depth in depths {
            // Dense-baseline multiplications for the ratio column.
            let dense_ref = network(b, depth, s, s.seed).dense_mults_per_example();
            for &method in methods {
                let grid_eff: &[f32] =
                    if method == Method::Standard { &[1.0] } else { grid };
                for &sp in grid_eff {
                    if method == Method::AdaptiveDropout && sp < 0.25 {
                        r.row(vec![
                            b.name().into(),
                            depth.to_string(),
                            method.name().into(),
                            format!("{sp:.2}"),
                            "div".into(),
                            "-".into(),
                        ]);
                        continue;
                    }
                    let net = network(b, depth, s, s.seed);
                    let mut trainer = Trainer::new(
                        net,
                        TrainConfig {
                            epochs: s.epochs,
                            optim: OptimConfig { lr: s.lr, ..Default::default() },
                            sampler: sampler_for(method, sp),
                            seed: s.seed,
                            eval_cap: s.test_cap,
                            verbose,
                            ..Default::default()
                        },
                    );
                    let rec = trainer.run(&train, &test);
                    // Train-time multiplications relative to a dense net
                    // (forward+backward+update ≈ 3x forward per example).
                    let denom = 3 * dense_ref * (s.epochs as u64) * (train.len() as u64);
                    let ratio = rec.total_mults() as f64 / denom as f64;
                    r.row(vec![
                        b.name().into(),
                        depth.to_string(),
                        method.name().into(),
                        format!("{sp:.2}"),
                        format!("{:.4}", rec.final_acc()),
                        format!("{ratio:.3}"),
                    ]);
                }
            }
        }
    }
    r
}

/// Fig 6: LSH-5% ASGD convergence across thread counts.
pub fn fig6(
    datasets: &[Benchmark],
    threads: &[usize],
    sparsity: f32,
    s: &ExperimentScale,
    verbose: bool,
) -> Report {
    let mut r = Report::new(
        "Fig 6: LSH ASGD convergence vs threads",
        &["dataset", "threads", "epoch", "test_acc", "train_loss"],
    );
    for &b in datasets {
        let (n_tr, n_te) = sizes(b, s);
        let (train, test) = b.generate(n_tr, n_te, s.seed);
        for &t in threads {
            let net = network(b, 3, s, s.seed);
            let out = run_asgd(
                net,
                &train,
                &test,
                &AsgdConfig {
                    threads: t,
                    epochs: s.epochs,
                    sampler: sampler_for(Method::Lsh, sparsity),
                    optim: OptimConfig { lr: s.lr, ..Default::default() },
                    seed: s.seed,
                    eval_cap: s.test_cap,
                    verbose,
                    ..Default::default()
                },
            );
            for e in &out.record.epochs {
                r.row(vec![
                    b.name().into(),
                    t.to_string(),
                    e.epoch.to_string(),
                    format!("{:.4}", e.test_acc),
                    format!("{:.4}", e.train_loss),
                ]);
            }
        }
    }
    r
}

/// Fig 7: LSH-5% vs dense STD under max-thread ASGD.
pub fn fig7(
    datasets: &[Benchmark],
    threads: usize,
    sparsity: f32,
    s: &ExperimentScale,
    verbose: bool,
) -> Report {
    let mut r = Report::new(
        "Fig 7: ASGD LSH vs STD",
        &["dataset", "method", "epoch", "test_acc"],
    );
    for &b in datasets {
        let (n_tr, n_te) = sizes(b, s);
        let (train, test) = b.generate(n_tr, n_te, s.seed);
        for (method, sp) in [(Method::Lsh, sparsity), (Method::Standard, 1.0)] {
            let net = network(b, 3, s, s.seed);
            let out = run_asgd(
                net,
                &train,
                &test,
                &AsgdConfig {
                    threads,
                    epochs: s.epochs,
                    sampler: sampler_for(method, sp),
                    optim: OptimConfig { lr: s.lr, ..Default::default() },
                    seed: s.seed,
                    eval_cap: s.test_cap,
                    verbose,
                    ..Default::default()
                },
            );
            for e in &out.record.epochs {
                r.row(vec![
                    b.name().into(),
                    method.name().into(),
                    e.epoch.to_string(),
                    format!("{:.4}", e.test_acc),
                ]);
            }
        }
    }
    r
}

/// Conflict-cost speedup model (DESIGN.md §3): on a machine with enough
/// cores, t Hogwild workers at measured active-set overlap `q` and serial
/// table-maintenance fraction `serial` achieve
///   speedup(t) = t / (1 + serial·(t-1) + q·(t-1))
/// — the paper's 31x/56-thread point corresponds to q+serial ≈ 0.0145,
/// and the small-dataset flattening comes from the (measured) rising
/// overlap when shards get short.
pub fn model_speedup(t: usize, overlap: f64, serial: f64) -> f64 {
    t as f64 / (1.0 + (serial + overlap) * (t as f64 - 1.0))
}

/// Fig 8: wall-clock per epoch vs threads (measured) + conflict-model
/// speedup (what the measured overlap predicts on a many-core box).
pub fn fig8(
    datasets: &[Benchmark],
    threads: &[usize],
    sparsity: f32,
    s: &ExperimentScale,
    verbose: bool,
) -> Report {
    let mut r = Report::new(
        "Fig 8: ASGD scaling",
        &[
            "dataset",
            "threads",
            "secs_per_epoch",
            "measured_speedup",
            "mean_overlap",
            "model_speedup",
            "final_acc",
        ],
    );
    for &b in datasets {
        let (n_tr, n_te) = sizes(b, s);
        let (train, test) = b.generate(n_tr, n_te, s.seed);
        let mut base_secs = None;
        for &t in threads {
            let net = network(b, 3, s, s.seed);
            let out = run_asgd(
                net,
                &train,
                &test,
                &AsgdConfig {
                    threads: t,
                    epochs: s.epochs.min(3),
                    sampler: sampler_for(Method::Lsh, sparsity),
                    optim: OptimConfig { lr: s.lr, ..Default::default() },
                    seed: s.seed,
                    eval_cap: s.test_cap.min(200),
                    conflict_sample_every: 10,
                    verbose,
                    ..Default::default()
                },
            );
            let secs = out.record.total_secs() / out.record.epochs.len() as f64;
            let base = *base_secs.get_or_insert(secs);
            let overlap = out.conflicts.mean_overlap;
            // Serial fraction: hash maintenance + epoch-boundary rebuilds,
            // estimated from the selection share of multiplications.
            let sel: u64 = out.record.epochs.iter().map(|e| e.mults.selection).sum();
            let tot: u64 = out.record.epochs.iter().map(|e| e.mults.total()).sum();
            let serial = (sel as f64 / tot.max(1) as f64) * 0.1; // maintenance is parallel except table writes
            r.row(vec![
                b.name().into(),
                t.to_string(),
                format!("{secs:.2}"),
                format!("{:.2}", base / secs),
                format!("{overlap:.4}"),
                format!("{:.2}", model_speedup(t, overlap, serial)),
                format!("{:.4}", out.record.final_acc()),
            ]);
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_lists_all_datasets() {
        let r = table3();
        assert_eq!(r.rows.len(), 4);
        assert!(r.render().contains("MNIST8M"));
        assert!(r.render().contains("8100000"));
    }

    #[test]
    fn model_speedup_shapes() {
        // Near-linear at tiny overlap, flattening as overlap grows.
        let lin = model_speedup(56, 0.005, 0.01);
        assert!(lin > 30.0 && lin < 56.0, "paper-like point: {lin}");
        let flat = model_speedup(56, 0.2, 0.01);
        assert!(flat < 6.0, "high-overlap regime must flatten: {flat}");
        assert!((model_speedup(1, 0.5, 0.5) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn scale_parse() {
        assert_eq!(ExperimentScale::parse("quick").unwrap().hidden, 128);
        assert_eq!(ExperimentScale::parse("paper").unwrap().hidden, 1000);
        assert!(ExperimentScale::parse("huge").is_err());
    }

    #[test]
    fn fig45_smoke_tiny() {
        // Minute-scale smoke: one dataset, two methods, tiny sizes.
        let s = ExperimentScale {
            hidden: 32,
            train_frac: 0.02,
            test_cap: 100,
            epochs: 1,
            lr: 1e-2,
            seed: 1,
        };
        let r = fig45(
            &[Benchmark::Rectangles],
            &[Method::Standard, Method::Lsh],
            &[2],
            &[0.25],
            &s,
            false,
        );
        assert_eq!(r.rows.len(), 2);
        for row in &r.rows {
            let acc: f32 = row[4].parse().unwrap();
            assert!((0.0..=1.0).contains(&acc));
        }
    }
}
