//! PJRT execution of AOT artifacts: load HLO text produced by
//! `python/compile/aot.py`, compile once on the CPU client, execute many
//! times from the rust hot path. Python is never involved at runtime.

use crate::tensor::matrix::Matrix;
use anyhow::{Context, Result};
use std::path::Path;

/// Process-wide PJRT client + compiled-executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(PjrtRuntime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + parse + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled artifact. All artifacts are lowered with `return_tuple=True`,
/// so execution yields one tuple literal that [`Executable::run`]
/// decomposes into per-output literals.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

// ---------------------------------------------------------------------------
// Literal conversion helpers
// ---------------------------------------------------------------------------

/// (rows x cols) f32 matrix -> rank-2 literal.
pub fn matrix_literal(m: &Matrix) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(m.as_slice()).reshape(&[m.rows() as i64, m.cols() as i64])?)
}

/// Flat f32 slice -> rank-1 literal.
pub fn vec_literal(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Batch of rows -> rank-2 literal (rows padded/truncated to `batch`
/// by cycling — PJRT shapes are static).
pub fn batch_literal(rows: &[&[f32]], batch: usize, dim: usize) -> Result<xla::Literal> {
    assert!(!rows.is_empty());
    let mut flat = Vec::with_capacity(batch * dim);
    for i in 0..batch {
        let r = rows[i % rows.len()];
        debug_assert_eq!(r.len(), dim);
        flat.extend_from_slice(r);
    }
    Ok(xla::Literal::vec1(&flat).reshape(&[batch as i64, dim as i64])?)
}

/// Labels -> rank-1 i32 literal (cycled to `batch`).
pub fn label_literal(ys: &[u32], batch: usize) -> Result<xla::Literal> {
    assert!(!ys.is_empty());
    let v: Vec<i32> = (0..batch).map(|i| ys[i % ys.len()] as i32).collect();
    Ok(xla::Literal::vec1(&v))
}

/// f32 scalar literal.
pub fn scalar_literal(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a literal to a Vec<f32>.
pub fn literal_to_f32s(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a literal to a Vec<i32>.
pub fn literal_to_i32s(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_literal_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let lit = matrix_literal(&m).unwrap();
        assert_eq!(literal_to_f32s(&lit).unwrap(), m.as_slice());
    }

    #[test]
    fn batch_literal_cycles_rows() {
        let r1 = [1.0f32, 2.0];
        let r2 = [3.0f32, 4.0];
        let rows: Vec<&[f32]> = vec![&r1, &r2];
        let lit = batch_literal(&rows, 5, 2).unwrap();
        let v = literal_to_f32s(&lit).unwrap();
        assert_eq!(v, vec![1., 2., 3., 4., 1., 2., 3., 4., 1., 2.]);
    }

    #[test]
    fn label_literal_cycles() {
        let lit = label_literal(&[7, 8], 3).unwrap();
        assert_eq!(literal_to_i32s(&lit).unwrap(), vec![7, 8, 7]);
    }
}
