//! The STD dense baseline (Figs 4/5/7) executed through the AOT artifacts:
//! the entire minibatch train step — forward, softmax-CE, backward, SGD —
//! is one compiled HLO module (`mlp_step_<variant>`), and evaluation is
//! another (`mlp_fwd_<variant>`). Parameters live as XLA literals and flow
//! step -> step without touching rust floats.

use crate::data::dataset::Dataset;
use crate::runtime::artifacts::ArtifactSet;
use crate::runtime::pjrt::{
    batch_literal, label_literal, literal_to_f32s, scalar_literal, Executable, PjrtRuntime,
};
use crate::train::metrics::{EpochRecord, MultCounters, RunRecord};
use crate::util::rng::Pcg64;
use anyhow::{Context, Result};
use std::time::Instant;

pub const STEP_BATCH: usize = 32;
pub const EVAL_BATCH: usize = 256;

/// Dense-baseline trainer over the PJRT artifacts.
pub struct StdBaseline {
    step_exe: Executable,
    fwd_exe: Executable,
    /// Current parameters as literals: [w1, b1, w2, b2, ...].
    params: Vec<xla::Literal>,
    input_dim: usize,
    n_classes: usize,
    /// Dense multiplications per example (for the paper's accounting).
    dense_mults_per_example: u64,
}

impl StdBaseline {
    /// Build from an artifact set; parameters are initialized in rust
    /// (Glorot, same scheme as the native network) and uploaded once.
    pub fn new(rt: &PjrtRuntime, arts: &ArtifactSet, seed: u64) -> Result<Self> {
        let step_exe = rt.load(&arts.step_path)?;
        let fwd_exe = rt.load(&arts.fwd_path)?;
        let mut rng = Pcg64::new(seed, 0x57D);
        let mut params = Vec::new();
        let mut dense_mults = 0u64;
        for &(n_in, n_out) in &arts.layer_dims {
            let w = crate::nn::init::glorot_uniform(n_out, n_in, &mut rng);
            params.push(crate::runtime::pjrt::matrix_literal(&w)?);
            params.push(crate::runtime::pjrt::vec_literal(&vec![0.0; n_out]));
            dense_mults += (n_in * n_out) as u64;
        }
        Ok(StdBaseline {
            step_exe,
            fwd_exe,
            params,
            input_dim: arts.input_dim,
            n_classes: arts.n_classes,
            dense_mults_per_example: dense_mults,
        })
    }

    /// One SGD minibatch step; returns the batch loss.
    pub fn train_batch(&mut self, xs: &[&[f32]], ys: &[u32], lr: f32) -> Result<f32> {
        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.params.len() + 3);
        // Clone_from? Literals are opaque handles; rebuild arg vec by value.
        for p in &self.params {
            args.push(clone_literal(p)?);
        }
        args.push(batch_literal(xs, STEP_BATCH, self.input_dim)?);
        args.push(label_literal(ys, STEP_BATCH)?);
        args.push(scalar_literal(lr));
        let mut out = self.step_exe.run(&args)?;
        let loss = out.remove(0).get_first_element::<f32>()?;
        self.params = out;
        Ok(loss)
    }

    /// Evaluate accuracy + mean loss over a dataset via the fwd artifact.
    pub fn evaluate(&self, xs: &[Vec<f32>], ys: &[u32]) -> Result<(f32, f32)> {
        let mut correct = 0usize;
        let mut loss_sum = 0.0f64;
        let mut n = 0usize;
        for chunk in xs.chunks(EVAL_BATCH).zip(ys.chunks(EVAL_BATCH)) {
            let (cx, cy) = chunk;
            let rows: Vec<&[f32]> = cx.iter().map(|v| v.as_slice()).collect();
            let mut args: Vec<xla::Literal> = Vec::with_capacity(self.params.len() + 1);
            for p in &self.params {
                args.push(clone_literal(p)?);
            }
            args.push(batch_literal(&rows, EVAL_BATCH, self.input_dim)?);
            let out = self.fwd_exe.run(&args)?;
            let logits = literal_to_f32s(&out[0])?;
            for (i, &y) in cy.iter().enumerate() {
                let row = &logits[i * self.n_classes..(i + 1) * self.n_classes];
                let (l, pred) = crate::nn::loss::softmax_xent(row, y);
                loss_sum += l as f64;
                correct += (pred == y) as usize;
                n += 1;
            }
        }
        Ok(((loss_sum / n as f64) as f32, correct as f32 / n as f32))
    }

    /// Full training run (paper Fig 7's STD-ASGD counterpart runs dense
    /// minibatch SGD; here the step itself is the compiled artifact).
    pub fn run(
        &mut self,
        train: &Dataset,
        test: &Dataset,
        epochs: usize,
        lr: f32,
        eval_cap: usize,
        seed: u64,
    ) -> Result<RunRecord> {
        let mut record = RunRecord {
            method: "STD-PJRT".into(),
            dataset: train.name.clone(),
            sparsity: 1.0,
            threads: 1,
            epochs: Vec::new(),
        };
        let mut rng = Pcg64::new(seed, 0xE9);
        for epoch in 0..epochs {
            let t0 = Instant::now();
            let order = train.epoch_order(&mut rng);
            let mut loss_sum = 0.0f64;
            let mut batches = 0usize;
            for chunk in order.chunks(STEP_BATCH) {
                let xs: Vec<&[f32]> =
                    chunk.iter().map(|&i| train.xs[i as usize].as_slice()).collect();
                let ys: Vec<u32> = chunk.iter().map(|&i| train.ys[i as usize]).collect();
                loss_sum += self.train_batch(&xs, &ys, lr)? as f64;
                batches += 1;
            }
            let cap = if eval_cap == 0 { test.len() } else { eval_cap.min(test.len()) };
            let (test_loss, test_acc) = self.evaluate(&test.xs[..cap], &test.ys[..cap])?;
            let mults = MultCounters {
                forward: self.dense_mults_per_example * order.len() as u64,
                backward: 2 * self.dense_mults_per_example * order.len() as u64,
                selection: 0,
                update: self.dense_mults_per_example * order.len() as u64,
            };
            record.epochs.push(EpochRecord {
                epoch,
                train_loss: (loss_sum / batches.max(1) as f64) as f32,
                test_loss,
                test_acc,
                mults,
                active_fraction: 1.0,
                wall_secs: t0.elapsed().as_secs_f64(),
            });
        }
        Ok(record)
    }
}

/// Literal "clone" via serialize round-trip (the crate exposes no Clone).
fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    // Literal implements conversion to/from raw data through reshape of a
    // copied vec1; use element type to dispatch.
    let ty = l.ty().context("literal type")?;
    let shape = l.array_shape()?;
    let dims: Vec<i64> = shape.dims().to_vec();
    match ty {
        xla::ElementType::F32 => {
            let v = l.to_vec::<f32>()?;
            Ok(xla::Literal::vec1(&v).reshape(&dims)?)
        }
        xla::ElementType::S32 => {
            let v = l.to_vec::<i32>()?;
            Ok(xla::Literal::vec1(&v).reshape(&dims)?)
        }
        other => anyhow::bail!("unsupported literal type {other:?}"),
    }
}
