//! Artifact registry: maps dataset variants to their AOT artifact paths
//! and declared layer shapes, cross-checked against the manifest emitted
//! by `python/compile/aot.py`. Error type is a plain `String` so the
//! registry stays dependency-free (the `anyhow`-flavored execution path
//! lives behind the `pjrt` feature).

use std::path::{Path, PathBuf};

type Result<T> = std::result::Result<T, String>;

/// Variant table — must stay in sync with `python/compile/model.py`
/// VARIANTS (the manifest check below catches drift).
pub const VARIANTS: &[(&str, usize, usize, usize, usize)] = &[
    // (name, input_dim, n_classes, hidden, depth)
    ("mnist", 784, 10, 1000, 3),
    ("norb", 2048, 5, 1000, 3),
    ("convex", 784, 2, 1000, 3),
    ("rectangles", 784, 2, 1000, 3),
    ("tiny", 16, 2, 32, 2),
];

/// LSH parameters baked into the simhash artifacts (aot.py).
pub const SIMHASH_K: usize = 6;
pub const SIMHASH_L: usize = 5;
pub const SIMHASH_BATCH: usize = 16;

#[derive(Clone, Debug)]
pub struct ArtifactSet {
    pub variant: String,
    pub input_dim: usize,
    pub n_classes: usize,
    /// (n_in, n_out) per layer.
    pub layer_dims: Vec<(usize, usize)>,
    pub step_path: PathBuf,
    pub fwd_path: PathBuf,
    pub simhash_path: PathBuf,
}

impl ArtifactSet {
    /// Resolve a variant's artifacts under `dir`, verifying files exist.
    pub fn resolve(dir: &Path, variant: &str) -> Result<Self> {
        let &(name, input_dim, n_classes, hidden, depth) = VARIANTS
            .iter()
            .find(|v| v.0 == variant)
            .ok_or_else(|| format!("unknown variant {variant:?}"))?;
        let mut dims = vec![input_dim];
        dims.extend(std::iter::repeat(hidden).take(depth));
        dims.push(n_classes);
        let layer_dims: Vec<(usize, usize)> =
            dims.windows(2).map(|w| (w[0], w[1])).collect();
        let set = ArtifactSet {
            variant: name.to_string(),
            input_dim,
            n_classes,
            layer_dims,
            step_path: dir.join(format!("mlp_step_{name}.hlo.txt")),
            fwd_path: dir.join(format!("mlp_fwd_{name}.hlo.txt")),
            simhash_path: dir.join(format!("simhash_{name}.hlo.txt")),
        };
        for p in [&set.step_path, &set.fwd_path, &set.simhash_path] {
            if !p.exists() {
                return Err(format!(
                    "missing artifact {} — run `make artifacts` first",
                    p.display()
                ));
            }
        }
        Ok(set)
    }

    /// Validate against the aot.py manifest (first arg of mlp_fwd must be
    /// the first weight matrix with our expected shape).
    pub fn check_manifest(&self, dir: &Path) -> Result<()> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .map_err(|e| format!("reading artifacts/manifest.txt: {e}"))?;
        let key = format!("mlp_fwd_{} ", self.variant);
        let line = text
            .lines()
            .find(|l| l.starts_with(&key))
            .ok_or_else(|| format!("manifest missing {key}"))?;
        let sig = line.split_once(' ').unwrap().1;
        let first = sig.split(';').next().unwrap_or("");
        let expect = format!(
            "{}x{}:float32",
            self.layer_dims[0].1, self.layer_dims[0].0
        );
        if first != expect {
            return Err(format!(
                "manifest drift: expected first param {expect}, manifest says {first}"
            ));
        }
        Ok(())
    }

    /// Default artifacts directory: $HASHDL_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("HASHDL_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_table_has_paper_architectures() {
        let m = VARIANTS.iter().find(|v| v.0 == "mnist").unwrap();
        assert_eq!((m.1, m.2, m.3, m.4), (784, 10, 1000, 3));
        let n = VARIANTS.iter().find(|v| v.0 == "norb").unwrap();
        assert_eq!((n.1, n.2), (2048, 5));
    }

    #[test]
    fn unknown_variant_rejected() {
        assert!(ArtifactSet::resolve(Path::new("/nonexistent"), "nope").is_err());
    }

    #[test]
    fn missing_files_reported() {
        let err = ArtifactSet::resolve(Path::new("/nonexistent"), "tiny").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn layer_dims_chain() {
        // Verified against a real dir only in integration tests; here just
        // check the dim chain construction via a fake resolve failure path.
        let dims = {
            let mut dims = vec![784usize];
            dims.extend(std::iter::repeat(1000).take(3));
            dims.push(10);
            dims.windows(2).map(|w| (w[0], w[1])).collect::<Vec<_>>()
        };
        assert_eq!(dims, vec![(784, 1000), (1000, 1000), (1000, 1000), (1000, 10)]);
    }
}
