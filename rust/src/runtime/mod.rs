//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered JAX/Pallas) and
//! executes them from rust. HLO text is the interchange format — see
//! python/compile/aot.py for why (proto id width mismatch).
//!
//! The artifact *registry* is always available; the execution path
//! ([`pjrt`], [`std_baseline`]) needs the vendored `xla` crate and is
//! gated behind the `pjrt` cargo feature so the default (offline,
//! std-only) build stays self-contained.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod std_baseline;

pub use artifacts::ArtifactSet;
#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, PjrtRuntime};
#[cfg(feature = "pjrt")]
pub use std_baseline::StdBaseline;
