//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered JAX/Pallas) and
//! executes them from rust. HLO text is the interchange format — see
//! python/compile/aot.py for why (proto id width mismatch).

pub mod artifacts;
pub mod pjrt;
pub mod std_baseline;

pub use artifacts::ArtifactSet;
pub use pjrt::{Executable, PjrtRuntime};
pub use std_baseline::StdBaseline;
