//! Sparse-aware optimizers. The paper trains with "stochastic gradient
//! descent with Momentum and Adagrad" (§6.2.1): Adagrad scales the raw
//! gradient by accumulated squared magnitude, Momentum smooths the scaled
//! step. All state updates touch only the (row, active-input) coordinates
//! of the active set — the property that makes Hogwild updates conflict-free.

use crate::nn::sparse::LayerInput;
use crate::tensor::matrix::Matrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    Momentum,
    Adagrad,
    /// Adagrad-normalized gradient fed through momentum (paper default).
    MomentumAdagrad,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "sgd" => Ok(OptimizerKind::Sgd),
            "momentum" => Ok(OptimizerKind::Momentum),
            "adagrad" => Ok(OptimizerKind::Adagrad),
            "momentum-adagrad" | "madagrad" => Ok(OptimizerKind::MomentumAdagrad),
            other => Err(format!("unknown optimizer {other:?}")),
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct OptimConfig {
    pub kind: OptimizerKind,
    pub lr: f32,
    /// Momentum decay γ.
    pub gamma: f32,
    /// Adagrad denominator fuzz.
    pub eps: f32,
}

impl Default for OptimConfig {
    fn default() -> Self {
        OptimConfig { kind: OptimizerKind::MomentumAdagrad, lr: 1e-2, gamma: 0.9, eps: 1e-8 }
    }
}

/// Per-layer optimizer state (same shape as the layer parameters).
#[derive(Clone, Debug)]
struct LayerState {
    velocity_w: Option<Matrix>,
    velocity_b: Option<Vec<f32>>,
    accum_w: Option<Matrix>,
    accum_b: Option<Vec<f32>>,
}

/// Optimizer over a whole network's parameters.
#[derive(Clone, Debug)]
pub struct Optimizer {
    pub cfg: OptimConfig,
    state: Vec<LayerState>,
}

impl Optimizer {
    /// `layer_dims`: (n_in, n_out) per layer.
    pub fn new(cfg: OptimConfig, layer_dims: &[(usize, usize)]) -> Self {
        let needs_vel =
            matches!(cfg.kind, OptimizerKind::Momentum | OptimizerKind::MomentumAdagrad);
        let needs_acc =
            matches!(cfg.kind, OptimizerKind::Adagrad | OptimizerKind::MomentumAdagrad);
        let state = layer_dims
            .iter()
            .map(|&(n_in, n_out)| LayerState {
                velocity_w: needs_vel.then(|| Matrix::zeros(n_out, n_in)),
                velocity_b: needs_vel.then(|| vec![0.0; n_out]),
                accum_w: needs_acc.then(|| Matrix::zeros(n_out, n_in)),
                accum_b: needs_acc.then(|| vec![0.0; n_out]),
            })
            .collect();
        Optimizer { cfg, state }
    }

    pub fn for_network(cfg: OptimConfig, net: &crate::nn::network::Network) -> Self {
        let dims: Vec<(usize, usize)> =
            net.layers.iter().map(|l| (l.n_in(), l.n_out())).collect();
        Self::new(cfg, &dims)
    }

    #[inline]
    fn step_value(
        kind: OptimizerKind,
        cfg: &OptimConfig,
        g: f32,
        vel: Option<&mut f32>,
        acc: Option<&mut f32>,
    ) -> f32 {
        let scaled = match kind {
            OptimizerKind::Sgd | OptimizerKind::Momentum => cfg.lr * g,
            OptimizerKind::Adagrad | OptimizerKind::MomentumAdagrad => {
                let a = acc.expect("adagrad state");
                *a += g * g;
                cfg.lr * g / (a.sqrt() + cfg.eps)
            }
        };
        match kind {
            OptimizerKind::Sgd | OptimizerKind::Adagrad => scaled,
            OptimizerKind::Momentum | OptimizerKind::MomentumAdagrad => {
                let v = vel.expect("momentum state");
                *v = cfg.gamma * *v + scaled;
                *v
            }
        }
    }

    /// Apply the update for one output neuron `row` of layer `layer`:
    /// grad(W[row][j]) = dz * a_j over the active input coordinates, and
    /// grad(b[row]) = dz. Mutates the weight row and bias in place.
    /// Returns multiplications performed.
    pub fn update_row(
        &mut self,
        layer: usize,
        row: usize,
        dz: f32,
        input: LayerInput<'_>,
        w_row: &mut [f32],
        b: &mut f32,
    ) -> u64 {
        let kind = self.cfg.kind;
        let cfg = self.cfg;
        let st = &mut self.state[layer];
        let mut mults;
        match input {
            LayerInput::Dense(x) => {
                mults = x.len() as u64;
                for (j, &xj) in x.iter().enumerate() {
                    let g = dz * xj;
                    let vel = st.velocity_w.as_mut().map(|m| &mut m.row_mut(row)[j]);
                    let acc = st.accum_w.as_mut().map(|m| &mut m.row_mut(row)[j]);
                    w_row[j] -= Self::step_value(kind, &cfg, g, vel, acc);
                }
            }
            LayerInput::Sparse(s) => {
                mults = s.len() as u64;
                for (&j, &xj) in s.idx.iter().zip(&s.val) {
                    let j = j as usize;
                    let g = dz * xj;
                    let vel = st.velocity_w.as_mut().map(|m| &mut m.row_mut(row)[j]);
                    let acc = st.accum_w.as_mut().map(|m| &mut m.row_mut(row)[j]);
                    w_row[j] -= Self::step_value(kind, &cfg, g, vel, acc);
                }
            }
        }
        let vel = st.velocity_b.as_mut().map(|v| &mut v[row]);
        let acc = st.accum_b.as_mut().map(|v| &mut v[row]);
        *b -= Self::step_value(kind, &cfg, dz, vel, acc);
        mults += 1;
        mults
    }

    /// Apply a pre-accumulated (minibatch) gradient for one output neuron.
    ///
    /// `grad` is a dense-length gradient row (only the listed coordinates
    /// are read); `cols: None` applies every coordinate (dense-input
    /// layers — matching [`Optimizer::update_row`], which also touches
    /// zero-gradient coordinates so momentum decay stays identical), while
    /// `Some(cols)` applies the batch's union of live input coordinates.
    /// With a batch of one the arithmetic is exactly `update_row`'s:
    /// accumulate `g = dz·x_j`, then the same `step_value` per coordinate.
    /// Returns multiplications performed.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_row_grad(
        &mut self,
        layer: usize,
        row: usize,
        cols: Option<&[u32]>,
        grad: &[f32],
        grad_b: f32,
        w_row: &mut [f32],
        b: &mut f32,
    ) -> u64 {
        let kind = self.cfg.kind;
        let cfg = self.cfg;
        let st = &mut self.state[layer];
        let mut mults;
        match cols {
            None => {
                mults = grad.len() as u64;
                for (j, &g) in grad.iter().enumerate() {
                    let vel = st.velocity_w.as_mut().map(|m| &mut m.row_mut(row)[j]);
                    let acc = st.accum_w.as_mut().map(|m| &mut m.row_mut(row)[j]);
                    w_row[j] -= Self::step_value(kind, &cfg, g, vel, acc);
                }
            }
            Some(cols) => {
                mults = cols.len() as u64;
                for &j in cols {
                    let j = j as usize;
                    let g = grad[j];
                    let vel = st.velocity_w.as_mut().map(|m| &mut m.row_mut(row)[j]);
                    let acc = st.accum_w.as_mut().map(|m| &mut m.row_mut(row)[j]);
                    w_row[j] -= Self::step_value(kind, &cfg, g, vel, acc);
                }
            }
        }
        let vel = st.velocity_b.as_mut().map(|v| &mut v[row]);
        let acc = st.accum_b.as_mut().map(|v| &mut v[row]);
        *b -= Self::step_value(kind, &cfg, grad_b, vel, acc);
        mults += 1;
        mults
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::sparse::SparseVec;

    fn mk(kind: OptimizerKind, lr: f32) -> Optimizer {
        Optimizer::new(OptimConfig { kind, lr, gamma: 0.9, eps: 1e-8 }, &[(4, 2)])
    }

    #[test]
    fn sgd_step_matches_formula() {
        let mut opt = mk(OptimizerKind::Sgd, 0.1);
        let x = [1.0, 2.0, 0.0, -1.0];
        let mut w = [0.0f32; 4];
        let mut b = 0.0f32;
        opt.update_row(0, 0, 0.5, LayerInput::Dense(&x), &mut w, &mut b);
        assert_eq!(w, [-0.05, -0.1, 0.0, 0.05]);
        assert!((b + 0.05).abs() < 1e-7);
    }

    #[test]
    fn sparse_update_touches_only_active_columns() {
        let mut opt = mk(OptimizerKind::Sgd, 0.1);
        let s = SparseVec::from_pairs(&[(1, 2.0)]);
        let mut w = [1.0f32; 4];
        let mut b = 0.0f32;
        opt.update_row(0, 1, 1.0, LayerInput::Sparse(&s), &mut w, &mut b);
        assert_eq!(w, [1.0, 0.8, 1.0, 1.0]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = mk(OptimizerKind::Momentum, 0.1);
        let x = [1.0, 0.0, 0.0, 0.0];
        let mut w = [0.0f32; 4];
        let mut b = 0.0f32;
        opt.update_row(0, 0, 1.0, LayerInput::Dense(&x), &mut w, &mut b);
        let w1 = w[0]; // -0.1
        opt.update_row(0, 0, 1.0, LayerInput::Dense(&x), &mut w, &mut b);
        // second step: v = 0.9*0.1 + 0.1 = 0.19 -> w = -0.29
        assert!((w1 + 0.1).abs() < 1e-6);
        assert!((w[0] + 0.29).abs() < 1e-6);
    }

    #[test]
    fn adagrad_shrinks_effective_lr() {
        let mut opt = mk(OptimizerKind::Adagrad, 0.1);
        let x = [1.0, 0.0, 0.0, 0.0];
        let mut w = [0.0f32; 4];
        let mut b = 0.0f32;
        opt.update_row(0, 0, 1.0, LayerInput::Dense(&x), &mut w, &mut b);
        let step1 = -w[0]; // lr * 1/sqrt(1) = 0.1
        let before = w[0];
        opt.update_row(0, 0, 1.0, LayerInput::Dense(&x), &mut w, &mut b);
        let step2 = before - w[0]; // lr / sqrt(2) ≈ 0.0707
        assert!((step1 - 0.1).abs() < 1e-5);
        assert!(step2 < step1);
        assert!((step2 - 0.1 / 2.0f32.sqrt()).abs() < 1e-4);
    }

    #[test]
    fn momentum_adagrad_composes() {
        let mut opt = mk(OptimizerKind::MomentumAdagrad, 0.1);
        let x = [1.0, 0.0, 0.0, 0.0];
        let mut w = [0.0f32; 4];
        let mut b = 0.0f32;
        opt.update_row(0, 0, 1.0, LayerInput::Dense(&x), &mut w, &mut b);
        // step = momentum(adagrad(g)) = 0.9*0 + 0.1*1/1 = 0.1
        assert!((w[0] + 0.1).abs() < 1e-5);
        opt.update_row(0, 0, 1.0, LayerInput::Dense(&x), &mut w, &mut b);
        // v = 0.9*0.1 + 0.1/sqrt(2) ≈ 0.1607
        assert!((w[0] + 0.2607).abs() < 1e-3);
    }

    #[test]
    fn apply_row_grad_matches_update_row_for_batch_of_one() {
        // For every optimizer kind, accumulating g = dz * x then applying
        // must be bitwise identical to the fused per-example update.
        for kind in [
            OptimizerKind::Sgd,
            OptimizerKind::Momentum,
            OptimizerKind::Adagrad,
            OptimizerKind::MomentumAdagrad,
        ] {
            let mut fused = mk(kind, 0.1);
            let mut split = mk(kind, 0.1);
            let x = [1.0f32, 2.0, 0.0, -1.0];
            let dz = 0.5f32;
            let (mut w_a, mut b_a) = ([0.2f32; 4], 0.1f32);
            let (mut w_b, mut b_b) = ([0.2f32; 4], 0.1f32);
            for _ in 0..3 {
                fused.update_row(0, 0, dz, LayerInput::Dense(&x), &mut w_a, &mut b_a);
                let grad: Vec<f32> = x.iter().map(|&xj| dz * xj).collect();
                split.apply_row_grad(0, 0, None, &grad, dz, &mut w_b, &mut b_b);
            }
            assert_eq!(w_a, w_b, "{kind:?} weights");
            assert_eq!(b_a, b_b, "{kind:?} bias");
        }
    }

    #[test]
    fn apply_row_grad_sparse_cols_touch_only_union() {
        let mut opt = mk(OptimizerKind::Sgd, 0.1);
        let grad = [0.0f32, 2.0, 0.0, -1.0];
        let mut w = [1.0f32; 4];
        let mut b = 0.0f32;
        let m = opt.apply_row_grad(0, 1, Some(&[1, 3]), &grad, 0.0, &mut w, &mut b);
        assert_eq!(w, [1.0, 0.8, 1.0, 1.1]);
        assert_eq!(m, 3);
    }

    #[test]
    fn parse_names() {
        assert_eq!(OptimizerKind::parse("sgd").unwrap(), OptimizerKind::Sgd);
        assert_eq!(
            OptimizerKind::parse("momentum-adagrad").unwrap(),
            OptimizerKind::MomentumAdagrad
        );
        assert!(OptimizerKind::parse("adam").is_err());
    }
}
