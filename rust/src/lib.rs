//! # hashdl — Scalable and Sustainable Deep Learning via Randomized Hashing
//!
//! A production-shaped reproduction of Spring & Shrivastava (KDD 2017):
//! fully-connected networks whose per-input active neuron set is selected
//! in sub-linear time by querying per-layer (K, L) asymmetric-LSH hash
//! tables, yielding ~5%-of-dense computation with ~dense accuracy and
//! conflict-free Hogwild ASGD scaling.
//!
//! Architecture (see DESIGN.md):
//! * **L3 (this crate)** — the coordinator: LSH substrate, sparse
//!   forward/backward, five node-selection policies, optimizers, Hogwild
//!   ASGD engine, synthetic dataset generators, experiment runner, CLI.
//! * **L2/L1 (python, build-time only)** — JAX dense MLP + Pallas simhash
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt`, executed from
//!   [`runtime`] via the PJRT CPU client (`xla` crate). Python never runs
//!   on the training path.

pub mod coordinator;
pub mod data;
pub mod exec;
pub mod lsh;
pub mod nn;
pub mod obs;
pub mod optim;
pub mod publish;
pub mod router;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::data::{Benchmark, Dataset};
    pub use crate::exec::{BatchExecutor, SparseBatchPlan, TableView};
    pub use crate::lsh::{LayerTables, LshConfig};
    pub use crate::nn::{Activation, Network, NetworkConfig};
    pub use crate::obs::{MetricsRegistry, MetricsSnapshot, TableHealth};
    pub use crate::optim::{OptimConfig, OptimizerKind};
    pub use crate::publish::{ModelParts, PublishedModel, TablePublisher, TableReader};
    pub use crate::router::{
        policy::RoutePolicy, registry::ModelRegistry, stats::RouterStats, RouteOutcome,
        RoutedRequest, Router,
    };
    pub use crate::sampling::{Method, SamplerConfig};
    pub use crate::serve::{
        load_snapshot, save_snapshot, InferenceWorkspace, ModelSnapshot, PoolConfig, ServePool,
        SparseInferenceEngine,
    };
    pub use crate::tensor::{Batch, BatchPlane, Matrix};
    pub use crate::train::{
        run_asgd, train_batch, AsgdConfig, BatchWorkspace, TrainConfig, Trainer,
    };
    pub use crate::util::rng::Pcg64;
}
