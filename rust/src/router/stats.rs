//! Fleet telemetry: the per-model and shadow-divergence snapshots the
//! router aggregates from pool counters and admission counts.
//!
//! Everything here is plain data copied out of lock-free counters —
//! calling [`crate::router::Router::stats`] mid-traffic costs relaxed
//! atomic loads per model, never a queue lock.

use crate::router::registry::ModelEntry;
use crate::serve::stats::VersionAgeSnapshot;
use crate::util::json::JsonObject;

/// Escape a string for embedding in a JSON string literal (re-exported
/// from the shared JSON writer — model names come from operator config
/// files, so quotes/backslashes/control bytes must not be interpolated
/// raw into `BENCH_router.json`).
pub use crate::util::json::escape as json_escape;

/// One model's view at a snapshot instant.
#[derive(Clone, Debug)]
pub struct ModelStatus {
    pub name: String,
    /// Newest version published into the model's slot.
    pub latest_version: u64,
    /// Requests the router admitted into the model's queue.
    pub accepted: u64,
    /// Requests shed at the model's bounded queue.
    pub shed: u64,
    /// Responses the pool has completed (≤ accepted while in flight).
    pub served: u64,
    /// Served responses per second since registration.
    pub req_per_sec: f64,
    /// In-pool latency percentiles (conservative octave upper bounds).
    pub p50_micros: u64,
    pub p99_micros: u64,
    /// Mean micro-batch size the pool's workers formed.
    pub mean_batch: f64,
    /// Worker re-pins to newer published versions.
    pub version_switches: u64,
    /// Staleness histogram: one sample per micro-batch of
    /// `latest_version − served_version`.
    pub version_age: VersionAgeSnapshot,
}

impl ModelStatus {
    /// Snapshot one registry entry (pool stats + admission counters).
    pub fn of(entry: &ModelEntry) -> ModelStatus {
        let pool = entry.pool_stats();
        ModelStatus {
            name: entry.name().to_string(),
            latest_version: entry.latest_version(),
            accepted: entry.accepted(),
            shed: entry.shed(),
            served: pool.requests,
            req_per_sec: pool.requests as f64 / entry.age_secs(),
            p50_micros: pool.p50_micros(),
            p99_micros: pool.p99_micros(),
            mean_batch: pool.mean_batch(),
            version_switches: pool.version_switches,
            version_age: pool.version_age,
        }
    }

    /// Fraction of offered requests shed at this model's queue.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.accepted + self.shed;
        if offered == 0 {
            0.0
        } else {
            self.shed as f64 / offered as f64
        }
    }

    /// JSON object literal (the shape shared by `Router::stats` dumps and
    /// `BENCH_router.json`).
    pub fn to_json(&self) -> String {
        JsonObject::new()
            .str("name", &self.name)
            .u64("latest_version", self.latest_version)
            .u64("accepted", self.accepted)
            .u64("shed", self.shed)
            .fixed("shed_rate", self.shed_rate(), 4)
            .u64("served", self.served)
            .fixed("req_per_sec", self.req_per_sec, 1)
            .u64("p50_micros", self.p50_micros)
            .u64("p99_micros", self.p99_micros)
            .fixed("mean_batch", self.mean_batch, 2)
            .u64("version_switches", self.version_switches)
            .raw("version_age", &self.version_age.to_json_array())
            .finish()
    }
}

/// Shadow-mode divergence tally (see `router::shadow`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ShadowStats {
    /// Requests the deterministic shadow sample selected for mirroring
    /// (`shadow_fraction` of primary traffic; unsampled requests take the
    /// plain primary path and appear nowhere else in this tally).
    pub sampled: u64,
    /// Primary/shadow response pairs compared.
    pub compared: u64,
    /// Pairs whose argmax predictions disagreed.
    pub pred_mismatches: u64,
    /// Largest |primary_logit − shadow_logit| seen across all pairs.
    pub max_abs_logit_diff: f32,
    /// Shadow duplicates shed at the shadow's queue (primary unaffected).
    pub shadow_shed: u64,
    /// Responses that arrived with no pending entry (late shadow answers
    /// after their pair was abandoned; 0 in healthy runs).
    pub unpaired: u64,
}

impl ShadowStats {
    /// Fraction of compared pairs whose predictions disagreed.
    pub fn mismatch_rate(&self) -> f64 {
        if self.compared == 0 {
            0.0
        } else {
            self.pred_mismatches as f64 / self.compared as f64
        }
    }

    pub fn to_json(&self) -> String {
        JsonObject::new()
            .u64("sampled", self.sampled)
            .u64("compared", self.compared)
            .u64("pred_mismatches", self.pred_mismatches)
            .fixed("mismatch_rate", self.mismatch_rate(), 4)
            .fixed("max_abs_logit_diff", self.max_abs_logit_diff as f64, 6)
            .u64("shadow_shed", self.shadow_shed)
            .u64("unpaired", self.unpaired)
            .finish()
    }
}

/// Whole-fleet snapshot: one [`ModelStatus`] per registered model (name
/// order) plus the shadow tally and the active policy name.
#[derive(Clone, Debug)]
pub struct RouterStats {
    pub policy: &'static str,
    pub models: Vec<ModelStatus>,
    pub shadow: ShadowStats,
}

impl RouterStats {
    pub fn model(&self, name: &str) -> Option<&ModelStatus> {
        self.models.iter().find(|m| m.name == name)
    }

    /// Total requests shed across the fleet.
    pub fn total_shed(&self) -> u64 {
        self.models.iter().map(|m| m.shed).sum()
    }

    /// Total responses served across the fleet.
    pub fn total_served(&self) -> u64 {
        self.models.iter().map(|m| m.served).sum()
    }

    pub fn to_json(&self) -> String {
        let mut models = crate::util::json::JsonArray::new();
        for m in &self.models {
            models.push_raw(&m.to_json());
        }
        JsonObject::new()
            .str("policy", self.policy)
            .raw("models", &models.finish())
            .raw("shadow", &self.shadow.to_json())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shed_rate_and_mismatch_rate_handle_zero() {
        let m = ModelStatus {
            name: "m".into(),
            latest_version: 0,
            accepted: 0,
            shed: 0,
            served: 0,
            req_per_sec: 0.0,
            p50_micros: 0,
            p99_micros: 0,
            mean_batch: 0.0,
            version_switches: 0,
            version_age: VersionAgeSnapshot::default(),
        };
        assert_eq!(m.shed_rate(), 0.0);
        assert_eq!(ShadowStats::default().mismatch_rate(), 0.0);
        let m2 = ModelStatus { accepted: 90, shed: 10, ..m };
        assert!((m2.shed_rate() - 0.1).abs() < 1e-12);
        let json = m2.to_json();
        assert!(json.contains("\"shed_rate\": 0.1000"), "{json}");
        assert!(json.contains("\"version_age\": [0, 0, 0, 0, 0, 0, 0, 0]"), "{json}");
    }

    #[test]
    fn model_names_are_json_escaped() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b"), "a\\\"b");
        assert_eq!(json_escape("a\\b"), "a\\\\b");
        assert_eq!(json_escape("a\nb"), "a\\u000ab");
        let named = ModelStatus {
            name: "we\"ird".into(),
            latest_version: 0,
            accepted: 0,
            shed: 0,
            served: 0,
            req_per_sec: 0.0,
            p50_micros: 0,
            p99_micros: 0,
            mean_batch: 0.0,
            version_switches: 0,
            version_age: VersionAgeSnapshot::default(),
        };
        assert!(named.to_json().contains("\"name\": \"we\\\"ird\""));
    }
}
