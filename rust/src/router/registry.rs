//! The model registry: named per-model serving pools with runtime
//! add / remove / hot-reload.
//!
//! Each registered model owns the full single-model serving stack PR 2–3
//! built — a [`TableReader`] (the read half of a publication slot), a
//! [`SparseInferenceEngine`] resolving through it, and a [`ServePool`]
//! with its *own* [`PoolConfig`] (a canary can run 1 worker while the
//! primary runs 8). Hot-reload needs no registry involvement at all:
//! whoever holds the paired `TablePublisher` publishes, and the model's
//! pool picks the new epoch up between micro-batches exactly as in the
//! single-model path. Registering a model frozen from a snapshot is the
//! publish-once special case.
//!
//! The registry map is a name → `Arc<ModelEntry>` table behind an
//! `RwLock`: the routing hot path takes a read lock for one clone of the
//! entry Arc (no allocation, no pool contact); add/remove take the write
//! lock briefly. A removed model's pool is drained before
//! [`ModelRegistry::deregister`] returns — every request already admitted
//! is answered; only *new* routes see `UnknownModel`.

use crate::publish::{publish_once, ModelParts, TableReader};
use crate::serve::engine::SparseInferenceEngine;
use crate::serve::pool::{PoolConfig, PoolHandle, PoolStats, ServePool};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// One registered model: the serving stack plus the router-side admission
/// counters.
pub struct ModelEntry {
    name: String,
    reader: TableReader,
    engine: SparseInferenceEngine,
    handle: PoolHandle,
    /// The running pool. `Mutex<Option<..>>` because shutdown consumes the
    /// pool; `None` only transiently during deregistration.
    pool: Mutex<Option<ServePool>>,
    cfg: PoolConfig,
    /// Requests the router admitted into this model's queue.
    pub(crate) accepted: AtomicU64,
    /// Requests shed at this model's bounded queue (admission control).
    pub(crate) shed: AtomicU64,
    registered_at: Instant,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Newest version published into this model's slot (hot-reload probe).
    pub fn latest_version(&self) -> u64 {
        self.reader.latest_version()
    }

    /// The model's input dimensionality (request validation / debugging).
    pub fn n_in(&self) -> usize {
        self.engine.current().net.n_in()
    }

    /// Cloneable submission handle onto this model's pool.
    pub fn handle(&self) -> &PoolHandle {
        &self.handle
    }

    /// Per-model pool configuration this entry was registered with.
    pub fn pool_config(&self) -> PoolConfig {
        self.cfg
    }

    /// Live pool statistics (empty default if the pool is mid-shutdown).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool
            .lock()
            .expect("registry entry poisoned")
            .as_ref()
            .map(|p| p.stats())
            .unwrap_or_default()
    }

    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Seconds since this model was registered (per-model req/s basis).
    pub fn age_secs(&self) -> f64 {
        self.registered_at.elapsed().as_secs_f64().max(1e-9)
    }
}

/// Name → model map with runtime registration. Share behind an `Arc`:
/// the router holds one handle, the operator (CLI / trainer) another.
#[derive(Default)]
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a model following a live publication slot: the entry
    /// serves whatever the paired `TablePublisher` installs (train-serve
    /// feeding a fleet). Fails on duplicate names — replacing a model is
    /// an explicit deregister + register, so an operator can never
    /// silently orphan a running pool.
    pub fn register(
        &self,
        name: &str,
        reader: TableReader,
        cfg: PoolConfig,
    ) -> Result<Arc<ModelEntry>, String> {
        if name.is_empty() {
            return Err("model name must be non-empty".into());
        }
        let engine = SparseInferenceEngine::live(reader.clone());
        let pool = ServePool::start(engine.clone(), cfg);
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            reader,
            engine,
            handle: pool.handle(),
            pool: Mutex::new(Some(pool)),
            cfg,
            accepted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            registered_at: Instant::now(),
        });
        let mut map = self.models.write().expect("registry poisoned");
        if map.contains_key(name) {
            // The freshly started pool must not leak its worker threads.
            let pool = entry.pool.lock().expect("registry entry poisoned").take();
            drop(map);
            if let Some(p) = pool {
                p.shutdown();
            }
            return Err(format!("model {name:?} is already registered"));
        }
        map.insert(name.to_string(), Arc::clone(&entry));
        drop(map);
        // Per-model admission counters in the global exporter, labelled by
        // model name. Re-registering the same name after a deregister
        // replaces the readers (same name + labels key).
        let labels = crate::obs::export::label("model", name);
        let e = Arc::clone(&entry);
        crate::obs::global().register_labeled_counter(
            "hashdl_router_accepted_total",
            &labels,
            move || e.accepted.load(Ordering::Relaxed) as f64,
        );
        let e = Arc::clone(&entry);
        crate::obs::global().register_labeled_counter(
            "hashdl_router_shed_total",
            &labels,
            move || e.shed.load(Ordering::Relaxed) as f64,
        );
        Ok(entry)
    }

    /// Register a frozen model (snapshot parts): a publisher that
    /// publishes exactly once and drops — the entry serves version 0
    /// forever. Malformed parts (table/layer mismatch) come back as
    /// `Err`, not a panic — this is the operator-input path.
    pub fn register_frozen(
        &self,
        name: &str,
        parts: ModelParts,
        cfg: PoolConfig,
    ) -> Result<Arc<ModelEntry>, String> {
        parts.validate().map_err(|e| format!("model {name:?}: {e}"))?;
        self.register(name, publish_once(parts), cfg)
    }

    /// Remove a model: new routes see `UnknownModel` immediately, then the
    /// pool is drained (every admitted request answered) and its final
    /// stats returned. `None` if the name was not registered.
    pub fn deregister(&self, name: &str) -> Option<PoolStats> {
        let entry = self.models.write().expect("registry poisoned").remove(name)?;
        let pool = entry.pool.lock().expect("registry entry poisoned").take();
        pool.map(|p| p.shutdown())
    }

    /// Deregister every model (shutdown path), returning final stats in
    /// name order.
    pub fn shutdown_all(&self) -> Vec<(String, PoolStats)> {
        let names = self.names();
        names
            .into_iter()
            .filter_map(|n| self.deregister(&n).map(|s| (n, s)))
            .collect()
    }

    /// Look up a model (one read lock + Arc clone — the routing hot path).
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.models.read().expect("registry poisoned").get(name).cloned()
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.models.read().expect("registry poisoned").keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().expect("registry poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of every entry, sorted by name (stats aggregation).
    pub fn entries(&self) -> Vec<Arc<ModelEntry>> {
        self.models.read().expect("registry poisoned").values().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::activation::Activation;
    use crate::nn::network::{Network, NetworkConfig};
    use crate::publish::TablePublisher;
    use crate::sampling::{Method, SamplerConfig};
    use crate::serve::snapshot::ModelSnapshot;
    use crate::util::rng::Pcg64;
    use std::sync::mpsc::channel;

    fn parts(seed: u64) -> ModelParts {
        let cfg = NetworkConfig { n_in: 8, hidden: vec![24], n_out: 3, act: Activation::ReLU };
        let net = Network::new(&cfg, &mut Pcg64::seeded(seed));
        ModelParts::from_snapshot(ModelSnapshot::without_tables(
            net,
            SamplerConfig::with_method(Method::Lsh, 0.25),
            seed,
        ))
    }

    #[test]
    fn register_get_deregister_lifecycle() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        reg.register_frozen("alpha", parts(1), PoolConfig::default()).unwrap();
        reg.register_frozen("beta", parts(2), PoolConfig { workers: 2, ..Default::default() })
            .unwrap();
        assert_eq!(reg.names(), vec!["alpha".to_string(), "beta".to_string()]);
        assert_eq!(reg.get("alpha").unwrap().name(), "alpha");
        assert_eq!(reg.get("beta").unwrap().pool_config().workers, 2);
        assert!(reg.get("gamma").is_none());

        let stats = reg.deregister("alpha").expect("was registered");
        assert_eq!(stats.requests, 0, "no traffic sent");
        assert!(reg.get("alpha").is_none());
        assert_eq!(reg.len(), 1);
        assert!(reg.deregister("alpha").is_none(), "double deregister is a no-op");
        assert_eq!(reg.shutdown_all().len(), 1);
        assert!(reg.is_empty());
    }

    #[test]
    fn duplicate_names_are_rejected_without_leaking_pools() {
        let reg = ModelRegistry::new();
        reg.register_frozen("m", parts(3), PoolConfig::default()).unwrap();
        let err = reg.register_frozen("m", parts(4), PoolConfig::default()).unwrap_err();
        assert!(err.contains("already registered"), "{err}");
        // The survivor still serves.
        let entry = reg.get("m").unwrap();
        let (tx, rx) = channel();
        let x: Vec<f32> = (0..8).map(|j| (j as f32 * 0.3).sin()).collect();
        assert!(entry.handle().submit(0, x, tx));
        assert_eq!(rx.recv().unwrap().version, 0);
        reg.shutdown_all();
    }

    #[test]
    fn deregistered_pool_drains_admitted_requests() {
        let reg = ModelRegistry::new();
        let entry = reg.register_frozen("m", parts(5), PoolConfig::default()).unwrap();
        let (tx, rx) = channel();
        let x: Vec<f32> = (0..8).map(|j| (j as f32 * 0.7).cos()).collect();
        for id in 0..20u64 {
            assert!(entry.handle().submit(id, x.clone(), tx.clone()));
        }
        drop(tx);
        let stats = reg.deregister("m").unwrap();
        assert_eq!(stats.requests, 20, "every admitted request answered before teardown");
        assert_eq!(rx.iter().count(), 20);
    }

    #[test]
    fn malformed_parts_are_rejected_as_err_not_panic() {
        let reg = ModelRegistry::new();
        let mut bad = parts(8);
        bad.tables.clear();
        let err = reg.register_frozen("bad", bad, PoolConfig::default()).unwrap_err();
        assert!(err.contains("\"bad\""), "{err}");
        assert!(reg.is_empty(), "nothing half-registered");
    }

    #[test]
    fn live_entry_follows_its_publisher() {
        let reg = ModelRegistry::new();
        let (mut publisher, reader) = TablePublisher::start(parts(6));
        let entry = reg.register("live", reader, PoolConfig::default()).unwrap();
        assert_eq!(entry.latest_version(), 0);
        publisher.publish(parts(7));
        assert_eq!(entry.latest_version(), 1, "hot-reload falls out of the publish slot");
        reg.shutdown_all();
    }
}
