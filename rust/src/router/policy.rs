//! Routing policies: how a [`crate::router::RoutedRequest`]'s model name
//! resolves to the model that actually serves it.
//!
//! Three policies, deliberately small and composable at the deployment
//! layer rather than inside the router:
//!
//! * **Exact** — the request's model name is the model. The fleet
//!   baseline; anything unrecognized is `UnknownModel`, never a guess.
//! * **Canary** — requests addressed to `primary` split between `primary`
//!   and `canary` by a *deterministic* hash of the request id. No RNG, no
//!   per-connection state: the same id lands on the same side on every
//!   router, every restart, every replay — so a bad canary's traffic can
//!   be re-run bit-for-bit against the primary after the fact (the same
//!   replayability contract `Response.version` gives publications).
//! * **Shadow** — requests addressed to `primary` are served by it *and*
//!   duplicated to `shadow`; the shadow's responses are discarded after
//!   divergence (argmax mismatch, max |Δlogit|) is recorded. Zero client
//!   impact, full-traffic validation of a new snapshot.
//!
//! Requests naming any *other* registered model are always routed exactly,
//! whatever the policy — canary/shadow scope to their primary only.

use crate::util::rng::splitmix64;

/// Fixed salt folded into the canary hash so the split is independent of
/// any other id-derived randomization in the system.
const CANARY_SALT: u64 = 0xCA4A_97E5_11D5_0B6C;

/// How the router resolves model names. See the module docs.
#[derive(Clone, Debug, PartialEq)]
pub enum RoutePolicy {
    /// Route every request to the model it names.
    Exact,
    /// Split traffic addressed to `primary`: a deterministic
    /// `canary_fraction` of request ids go to `canary` instead.
    Canary { primary: String, canary: String, canary_fraction: f64 },
    /// Serve traffic addressed to `primary` from it, and duplicate every
    /// such request to `shadow`, recording divergence.
    Shadow { primary: String, shadow: String },
}

impl RoutePolicy {
    /// Human-readable policy name (stats / JSON).
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::Exact => "exact",
            RoutePolicy::Canary { .. } => "canary",
            RoutePolicy::Shadow { .. } => "shadow",
        }
    }
}

/// Deterministic canary assignment: `true` = route id to the canary.
///
/// The id is mixed through SplitMix64 and the top 53 bits compared
/// against `fraction` — a pure function, so replays and multi-router
/// deployments agree, and over any large id set the realized split
/// concentrates tightly around `fraction` (binomial: ±0.3% at 10k
/// requests for a 10% canary).
pub fn canary_assignment(id: u64, fraction: f64) -> bool {
    if fraction <= 0.0 {
        return false;
    }
    if fraction >= 1.0 {
        return true;
    }
    let mut state = id ^ CANARY_SALT;
    let h = splitmix64(&mut state);
    // Top 53 bits → uniform in [0, 1) at full f64 precision.
    let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    u < fraction
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_a_pure_function_of_id() {
        for id in 0..1000u64 {
            assert_eq!(canary_assignment(id, 0.1), canary_assignment(id, 0.1));
        }
    }

    #[test]
    fn realized_fraction_concentrates() {
        let n = 100_000u64;
        let hits = (0..n).filter(|&id| canary_assignment(id, 0.1)).count() as f64;
        let realized = hits / n as f64;
        assert!(
            (realized - 0.1).abs() < 0.005,
            "realized {realized} should sit within 0.5% of 10% over {n} ids"
        );
    }

    #[test]
    fn edge_fractions_are_total() {
        assert!(!canary_assignment(7, 0.0));
        assert!(!canary_assignment(7, -1.0));
        assert!(canary_assignment(7, 1.0));
        assert!(canary_assignment(7, 2.0));
    }

    #[test]
    fn monotone_in_fraction_per_id() {
        // The same id flips from primary to canary at exactly one
        // threshold — raising the fraction never un-assigns a canary id
        // (safe ramp-ups: 5% → 10% only *adds* canary traffic).
        for id in 0..200u64 {
            let mut was = false;
            for f in [0.01, 0.05, 0.1, 0.3, 0.7, 0.99] {
                let now = canary_assignment(id, f);
                assert!(now || !was, "id {id} left the canary when fraction rose to {f}");
                was = now;
            }
        }
    }

    #[test]
    fn policy_names() {
        assert_eq!(RoutePolicy::Exact.name(), "exact");
        let c = RoutePolicy::Canary {
            primary: "a".into(),
            canary: "b".into(),
            canary_fraction: 0.1,
        };
        assert_eq!(c.name(), "canary");
        let s = RoutePolicy::Shadow { primary: "a".into(), shadow: "b".into() };
        assert_eq!(s.name(), "shadow");
    }
}
