//! Routing policies: how a [`crate::router::RoutedRequest`]'s model name
//! resolves to the model that actually serves it.
//!
//! Three policies, deliberately small and composable at the deployment
//! layer rather than inside the router:
//!
//! * **Exact** — the request's model name is the model. The fleet
//!   baseline; anything unrecognized is `UnknownModel`, never a guess.
//! * **Canary** — requests addressed to `primary` split between `primary`
//!   and `canary` by a *deterministic* hash of the request id. No RNG, no
//!   per-connection state: the same id lands on the same side on every
//!   router, every restart, every replay — so a bad canary's traffic can
//!   be re-run bit-for-bit against the primary after the fact (the same
//!   replayability contract `Response.version` gives publications).
//! * **Shadow** — a deterministic `shadow_fraction` of requests addressed
//!   to `primary` are served by it *and* duplicated to `shadow`; the
//!   shadow's responses are discarded after divergence (argmax mismatch,
//!   max |Δlogit|) is recorded. Zero client impact. The sample is the
//!   same SplitMix64 id-hash as the canary split (under its own salt, so
//!   the two assignments are independent): at 1.0 every request is
//!   mirrored (full-traffic validation window), at e.g. 0.05 a permanent
//!   always-on shadow costs 5% extra compute — affordable for heavy
//!   fleets — while the mirrored subset is a pure function of the ids,
//!   so replays reproduce it exactly.
//!
//! Requests naming any *other* registered model are always routed exactly,
//! whatever the policy — canary/shadow scope to their primary only.

use crate::util::rng::splitmix64;

/// Fixed salt folded into the canary hash so the split is independent of
/// any other id-derived randomization in the system.
const CANARY_SALT: u64 = 0xCA4A_97E5_11D5_0B6C;

/// Salt for the shadow sample — distinct from [`CANARY_SALT`] so whether
/// a request is mirrored is independent of whether it would canary.
const SHADOW_SALT: u64 = 0x5EAD_0F0E_6B2C_91D3;

/// How the router resolves model names. See the module docs.
#[derive(Clone, Debug, PartialEq)]
pub enum RoutePolicy {
    /// Route every request to the model it names.
    Exact,
    /// Split traffic addressed to `primary`: a deterministic
    /// `canary_fraction` of request ids go to `canary` instead.
    Canary { primary: String, canary: String, canary_fraction: f64 },
    /// Serve traffic addressed to `primary` from it, and duplicate a
    /// deterministic `shadow_fraction` of those requests to `shadow`,
    /// recording divergence (1.0 = mirror everything).
    Shadow { primary: String, shadow: String, shadow_fraction: f64 },
}

impl RoutePolicy {
    /// Human-readable policy name (stats / JSON).
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::Exact => "exact",
            RoutePolicy::Canary { .. } => "canary",
            RoutePolicy::Shadow { .. } => "shadow",
        }
    }
}

/// Deterministic salted id-hash assignment: `true` = the id is in the
/// `fraction`-sized sample. The id is mixed through SplitMix64 under
/// `salt` and the top 53 bits compared against `fraction` — a pure
/// function, so replays and multi-router deployments agree, and over any
/// large id set the realized split concentrates tightly around
/// `fraction` (binomial: ±0.3% at 10k requests for a 10% sample).
fn hash_assignment(id: u64, fraction: f64, salt: u64) -> bool {
    if fraction <= 0.0 {
        return false;
    }
    if fraction >= 1.0 {
        return true;
    }
    let mut state = id ^ salt;
    let h = splitmix64(&mut state);
    // Top 53 bits → uniform in [0, 1) at full f64 precision.
    let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    u < fraction
}

/// Deterministic canary assignment: `true` = route id to the canary.
pub fn canary_assignment(id: u64, fraction: f64) -> bool {
    hash_assignment(id, fraction, CANARY_SALT)
}

/// Deterministic shadow-sample assignment: `true` = mirror this id to the
/// shadow model. Salted independently of [`canary_assignment`], so the
/// mirrored subset is uncorrelated with any canary split on the same ids.
pub fn shadow_assignment(id: u64, fraction: f64) -> bool {
    hash_assignment(id, fraction, SHADOW_SALT)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_a_pure_function_of_id() {
        for id in 0..1000u64 {
            assert_eq!(canary_assignment(id, 0.1), canary_assignment(id, 0.1));
        }
    }

    #[test]
    fn realized_fraction_concentrates() {
        let n = 100_000u64;
        let hits = (0..n).filter(|&id| canary_assignment(id, 0.1)).count() as f64;
        let realized = hits / n as f64;
        assert!(
            (realized - 0.1).abs() < 0.005,
            "realized {realized} should sit within 0.5% of 10% over {n} ids"
        );
    }

    #[test]
    fn edge_fractions_are_total() {
        assert!(!canary_assignment(7, 0.0));
        assert!(!canary_assignment(7, -1.0));
        assert!(canary_assignment(7, 1.0));
        assert!(canary_assignment(7, 2.0));
    }

    #[test]
    fn monotone_in_fraction_per_id() {
        // The same id flips from primary to canary at exactly one
        // threshold — raising the fraction never un-assigns a canary id
        // (safe ramp-ups: 5% → 10% only *adds* canary traffic).
        for id in 0..200u64 {
            let mut was = false;
            for f in [0.01, 0.05, 0.1, 0.3, 0.7, 0.99] {
                let now = canary_assignment(id, f);
                assert!(now || !was, "id {id} left the canary when fraction rose to {f}");
                was = now;
            }
        }
    }

    #[test]
    fn policy_names() {
        assert_eq!(RoutePolicy::Exact.name(), "exact");
        let c = RoutePolicy::Canary {
            primary: "a".into(),
            canary: "b".into(),
            canary_fraction: 0.1,
        };
        assert_eq!(c.name(), "canary");
        let s = RoutePolicy::Shadow {
            primary: "a".into(),
            shadow: "b".into(),
            shadow_fraction: 1.0,
        };
        assert_eq!(s.name(), "shadow");
    }

    #[test]
    fn shadow_sample_is_deterministic_and_independent_of_canary() {
        let n = 100_000u64;
        let hits = (0..n).filter(|&id| shadow_assignment(id, 0.1)).count() as f64;
        assert!(
            (hits / n as f64 - 0.1).abs() < 0.005,
            "realized shadow fraction {} should concentrate at 10%",
            hits / n as f64
        );
        for id in 0..1000u64 {
            assert_eq!(shadow_assignment(id, 0.3), shadow_assignment(id, 0.3));
        }
        // Independence: among canaried ids, the shadow rate stays ~10%
        // (identical salts would make the two samples nest perfectly).
        let canaried: Vec<u64> = (0..n).filter(|&id| canary_assignment(id, 0.5)).collect();
        let both = canaried.iter().filter(|&&id| shadow_assignment(id, 0.1)).count() as f64;
        let rate = both / canaried.len() as f64;
        assert!((rate - 0.1).abs() < 0.01, "shadow|canary rate {rate} should stay ~10%");
        // Edge fractions are total.
        assert!(!shadow_assignment(7, 0.0));
        assert!(shadow_assignment(7, 1.0));
    }
}
