//! Multi-model serving router: the fleet front door.
//!
//! PR 2–3 built the single-model serving path — frozen snapshots, a
//! micro-batching [`crate::serve::ServePool`] and lock-free live
//! publication. This subsystem puts the missing front end on it: one
//! process serving **many** models, each behind its own pool, with the
//! routing, admission-control and telemetry glue a fleet needs (the
//! SLIDE-style "smart algorithms on commodity CPUs" argument only pays at
//! fleet scale if one box can host the whole fleet).
//!
//! Pieces:
//! * [`registry::ModelRegistry`] — name → {[`crate::publish::TableReader`],
//!   [`crate::serve::ServePool`], per-model [`crate::serve::PoolConfig`]}
//!   with runtime add/remove. Per-model hot-reload falls out of the
//!   publish slot: a trainer publishes into its registered model while
//!   every other model serves frozen snapshots.
//! * [`policy::RoutePolicy`] — exact-name, deterministic canary split
//!   (pure function of the request id → replays reproduce), and shadow
//!   mirroring with divergence recording.
//! * [`Router`] — resolves [`RoutedRequest`]s through the policy and the
//!   registry, shedding at each model's bounded queue instead of
//!   blocking ([`RouteOutcome::Shed`]), and aggregates
//!   [`stats::RouterStats`]: per-model p50/p99, req/s, shed rate and the
//!   version-age histogram (`Response.version` vs the reader's
//!   `latest_version`).
//!
//! The routing hot path costs one registry read-lock (an Arc clone), one
//! hash for canary policies, one bounded-queue try-push, and one small
//! String allocation for the outcome's realized model name (how canary
//! splits are observed). Shadow mode adds a relay hop for primary
//! responses — the price of observing them — and is meant for validation
//! windows, not steady state.

pub mod policy;
pub mod registry;
pub mod stats;

use crate::serve::pool::{Response, SubmitOutcome};
use policy::{canary_assignment, shadow_assignment, RoutePolicy};
use registry::ModelRegistry;
use stats::{ModelStatus, RouterStats, ShadowStats};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};

/// One request addressed to the fleet: a model name plus the payload.
#[derive(Clone, Debug)]
pub struct RoutedRequest {
    pub id: u64,
    pub model: String,
    pub x: Vec<f32>,
}

/// What the router did with a request. `Enqueued.model` reports the model
/// that will actually answer — under a canary policy that is how the
/// realized split is observed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RouteOutcome {
    /// Admitted; the reply channel will receive the response from `model`.
    Enqueued { model: String },
    /// Shed at `model`'s bounded queue — rejected immediately, never
    /// queued unboundedly. No response will come.
    Shed { model: String },
    /// The request named a model that is not registered.
    UnknownModel,
    /// `model`'s pool is shutting down (deregistration race).
    Closed { model: String },
}

impl RouteOutcome {
    pub fn is_enqueued(&self) -> bool {
        matches!(self, RouteOutcome::Enqueued { .. })
    }
}

/// A primary/shadow pair mid-flight. Entries live in
/// [`ShadowShared::pending`] from admission until both responses arrive
/// (or the pair is abandoned on a failed submission). Keyed by a
/// router-internal serial — NOT the caller's request id, which the caller
/// is free to reuse while an earlier shadowed request is still in flight.
struct Pending {
    client: Sender<Response>,
    /// The caller's request id, restored on the forwarded response (the
    /// pools see the internal key instead).
    original_id: u64,
    /// Whether a shadow duplicate was actually admitted (false once the
    /// shadow queue sheds it — the primary then forwards immediately).
    expect_shadow: bool,
    primary: Option<Response>,
    shadow: Option<Response>,
}

/// State shared between the router and its two shadow drainer threads.
#[derive(Default)]
struct ShadowShared {
    pending: Mutex<HashMap<u64, Pending>>,
    tally: Mutex<ShadowStats>,
    /// Internal pending-map key source (collision-free even when callers
    /// reuse request ids).
    next_key: AtomicU64,
}

impl ShadowShared {
    /// Record one compared pair into the tally.
    fn record_pair(&self, primary: &Response, shadow: &Response) {
        let mut t = self.tally.lock().expect("shadow tally poisoned");
        t.compared += 1;
        t.pred_mismatches += u64::from(primary.pred != shadow.pred);
        match (&primary.logits, &shadow.logits) {
            (Some(a), Some(b)) if a.len() == b.len() => {
                let d = a
                    .iter()
                    .zip(b)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0f32, f32::max);
                if d > t.max_abs_logit_diff {
                    t.max_abs_logit_diff = d;
                }
            }
            // Shape mismatch (models with different output widths) is a
            // divergence by definition.
            _ => {
                t.pred_mismatches += u64::from(primary.pred == shadow.pred);
                t.max_abs_logit_diff = f32::INFINITY;
            }
        }
    }

    fn note_unpaired(&self) {
        self.tally.lock().expect("shadow tally poisoned").unpaired += 1;
    }

    fn note_shadow_shed(&self) {
        self.tally.lock().expect("shadow tally poisoned").shadow_shed += 1;
    }
}

/// Drain primary responses: forward each to its client (logits stripped —
/// they were requested for divergence scoring, not for the client), then
/// pair-and-record or park depending on the shadow's progress.
fn primary_drainer(shared: Arc<ShadowShared>, rx: Receiver<Response>) {
    while let Ok(resp) = rx.recv() {
        let mut pending = shared.pending.lock().expect("shadow pending poisoned");
        let Some(entry) = pending.get_mut(&resp.id) else {
            drop(pending);
            shared.note_unpaired();
            continue;
        };
        let forwarded = Response {
            id: entry.original_id,
            pred: resp.pred,
            version: resp.version,
            mults: resp.mults,
            queue_micros: resp.queue_micros,
            batch_size: resp.batch_size,
            logits: None,
        };
        // Client may have given up (dropped receiver) — divergence is
        // still worth recording.
        let _ = entry.client.send(forwarded);
        if !entry.expect_shadow {
            pending.remove(&resp.id);
        } else if entry.shadow.is_some() {
            let entry = pending.remove(&resp.id).expect("entry just read");
            let shadow = entry.shadow.expect("checked above");
            drop(pending);
            shared.record_pair(&resp, &shadow);
        } else {
            entry.primary = Some(resp);
        }
    }
}

/// Drain shadow responses: never forwarded anywhere — compared against
/// the primary's answer and dropped.
fn shadow_drainer(shared: Arc<ShadowShared>, rx: Receiver<Response>) {
    while let Ok(resp) = rx.recv() {
        let mut pending = shared.pending.lock().expect("shadow pending poisoned");
        let Some(entry) = pending.get_mut(&resp.id) else {
            drop(pending);
            shared.note_unpaired();
            continue;
        };
        if entry.primary.is_some() {
            let entry = pending.remove(&resp.id).expect("entry just read");
            let primary = entry.primary.expect("checked above");
            drop(pending);
            shared.record_pair(&primary, &resp);
        } else {
            entry.shadow = Some(resp);
        }
    }
}

/// Canary-split observability: how many primary-addressed requests the
/// deterministic hash diverted vs kept (relaxed atomics, exporter-only).
#[derive(Default)]
struct CanaryCounters {
    diverted: AtomicU64,
    kept: AtomicU64,
}

/// The fleet front-end. Cheap reads on the hot path; policy swaps and
/// registry changes take effect on the next route call.
pub struct Router {
    registry: Arc<ModelRegistry>,
    policy: RwLock<RoutePolicy>,
    shadow: Arc<ShadowShared>,
    canary: Arc<CanaryCounters>,
    primary_tx: Sender<Response>,
    shadow_tx: Sender<Response>,
    drainers: Vec<std::thread::JoinHandle<()>>,
}

impl Router {
    /// Front a registry with the [`RoutePolicy::Exact`] policy. The two
    /// shadow drainer threads start parked on empty channels; they cost
    /// nothing until a shadow policy routes traffic through them.
    pub fn new(registry: Arc<ModelRegistry>) -> Router {
        let shared = Arc::new(ShadowShared::default());
        let (primary_tx, primary_rx) = channel();
        let (shadow_tx, shadow_rx) = channel();
        let drainers = vec![
            {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name("hashdl-shadow-primary".into())
                    .spawn(move || primary_drainer(shared, primary_rx))
                    .expect("spawn shadow primary drainer")
            },
            {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name("hashdl-shadow-shadow".into())
                    .spawn(move || shadow_drainer(shared, shadow_rx))
                    .expect("spawn shadow drainer")
            },
        ];
        let canary = Arc::new(CanaryCounters::default());
        let c = Arc::clone(&canary);
        crate::obs::global().register_counter("hashdl_router_canary_diverted_total", move || {
            c.diverted.load(Ordering::Relaxed) as f64
        });
        let c = Arc::clone(&canary);
        crate::obs::global().register_counter("hashdl_router_canary_kept_total", move || {
            c.kept.load(Ordering::Relaxed) as f64
        });
        Router {
            registry,
            policy: RwLock::new(RoutePolicy::Exact),
            shadow: shared,
            canary,
            primary_tx,
            shadow_tx,
            drainers,
        }
    }

    /// The registry this router fronts.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Swap the routing policy (next route call sees it).
    pub fn set_policy(&self, policy: RoutePolicy) {
        *self.policy.write().expect("policy poisoned") = policy;
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy.read().expect("policy poisoned").clone()
    }

    /// Route one request. On [`RouteOutcome::Enqueued`] the `reply`
    /// channel receives exactly one [`Response`]; every other outcome
    /// means no response will come — the caller owns the retry/drop
    /// decision.
    ///
    /// Resolution happens under the policy read-lock without cloning the
    /// policy — beyond the queue entry, the only allocation is the
    /// outcome's realized model name; the shadow path additionally clones
    /// its two target names so the lock can be released before the double
    /// submission.
    pub fn route(&self, req: RoutedRequest, reply: &Sender<Response>) -> RouteOutcome {
        let policy = self.policy.read().expect("policy poisoned");
        match &*policy {
            RoutePolicy::Exact => self.submit(&req.model, req.id, req.x, false, reply.clone()),
            RoutePolicy::Canary { primary, canary, canary_fraction } => {
                let diverted =
                    req.model == *primary && canary_assignment(req.id, *canary_fraction);
                if req.model == *primary {
                    if diverted {
                        self.canary.diverted.fetch_add(1, Ordering::Relaxed);
                        crate::obs::events::emit(
                            crate::obs::EventKind::CanaryDecision,
                            canary,
                            req.id,
                            "diverted",
                        );
                    } else {
                        self.canary.kept.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let target: &str = if diverted { canary } else { &req.model };
                self.submit(target, req.id, req.x, false, reply.clone())
            }
            RoutePolicy::Shadow { primary, shadow, shadow_fraction } => {
                if req.model != *primary {
                    return self.submit(&req.model, req.id, req.x, false, reply.clone());
                }
                // Deterministic shadow sampling: unsampled ids take the
                // plain primary path — no pending entry, no logits, no
                // second submission — so a permanent small-fraction shadow
                // costs only its fraction of extra compute.
                if !shadow_assignment(req.id, *shadow_fraction) {
                    return self.submit(&req.model, req.id, req.x, false, reply.clone());
                }
                let (primary, shadow) = (primary.clone(), shadow.clone());
                drop(policy);
                self.route_shadowed(&primary, &shadow, req, reply)
            }
        }
    }

    /// Shadow-mode admission: pending entry first (so no response can
    /// outrun its bookkeeping), then the shadow duplicate, then the
    /// primary. The primary's outcome is the client's outcome; the
    /// shadow's failures only dent the divergence sample.
    ///
    /// Both submissions travel under a router-internal serial key instead
    /// of the caller's id — callers may legally reuse ids while an
    /// earlier shadowed request is in flight, and a pending-map collision
    /// would cross-deliver answers. The forwarded response restores the
    /// caller's id.
    fn route_shadowed(
        &self,
        primary: &str,
        shadow: &str,
        req: RoutedRequest,
        reply: &Sender<Response>,
    ) -> RouteOutcome {
        // One sampled request = one tally entry, counted at admission
        // (before either submission can fail) so `sampled` is the exact
        // denominator for the mirror's shed/compare rates.
        self.shadow.tally.lock().expect("shadow tally poisoned").sampled += 1;
        let key = self.shadow.next_key.fetch_add(1, Ordering::Relaxed);
        {
            let mut pending = self.shadow.pending.lock().expect("shadow pending poisoned");
            pending.insert(
                key,
                Pending {
                    client: reply.clone(),
                    original_id: req.id,
                    expect_shadow: true,
                    primary: None,
                    shadow: None,
                },
            );
        }
        let shadow_out = self.submit(shadow, key, req.x.clone(), true, self.shadow_tx.clone());
        if !shadow_out.is_enqueued() {
            self.shadow.note_shadow_shed();
            if let Some(entry) = self
                .shadow
                .pending
                .lock()
                .expect("shadow pending poisoned")
                .get_mut(&key)
            {
                entry.expect_shadow = false;
            }
        }
        let primary_out = self.submit(primary, key, req.x, true, self.primary_tx.clone());
        if !primary_out.is_enqueued() {
            // No primary response will come; abandon the pair. A shadow
            // response that already landed in the entry dies with it.
            self.shadow.pending.lock().expect("shadow pending poisoned").remove(&key);
        }
        primary_out
    }

    /// Admission-controlled submission to one named model.
    fn submit(
        &self,
        model: &str,
        id: u64,
        x: Vec<f32>,
        want_logits: bool,
        reply: Sender<Response>,
    ) -> RouteOutcome {
        let Some(entry) = self.registry.get(model) else {
            return RouteOutcome::UnknownModel;
        };
        match entry.handle().try_submit(id, x, want_logits, reply) {
            SubmitOutcome::Enqueued => {
                entry.accepted.fetch_add(1, Ordering::Relaxed);
                RouteOutcome::Enqueued { model: model.to_string() }
            }
            SubmitOutcome::QueueFull => {
                let n = entry.shed.fetch_add(1, Ordering::Relaxed) + 1;
                crate::obs::events::emit(crate::obs::EventKind::Shed, model, n, "queue_full");
                RouteOutcome::Shed { model: model.to_string() }
            }
            SubmitOutcome::Closed => RouteOutcome::Closed { model: model.to_string() },
        }
    }

    /// Fleet snapshot: per-model status (name order) + shadow tally.
    pub fn stats(&self) -> RouterStats {
        RouterStats {
            policy: self.policy.read().expect("policy poisoned").name(),
            models: self.registry.entries().iter().map(|e| ModelStatus::of(e)).collect(),
            shadow: *self.shadow.tally.lock().expect("shadow tally poisoned"),
        }
    }

    /// Shadow divergence tally so far.
    pub fn shadow_stats(&self) -> ShadowStats {
        *self.shadow.tally.lock().expect("shadow tally poisoned")
    }

    /// Tear down the shadow drainers and return the final divergence
    /// tally. Joins wait for in-flight shadowed requests, so drain or
    /// shut down the registry's pools first if traffic may still be
    /// queued. The registry itself is left running — it may outlive the
    /// router (e.g. a policy-object swap).
    pub fn shutdown(self) -> ShadowStats {
        let Router { shadow, drainers, primary_tx, shadow_tx, .. } = self;
        drop(primary_tx);
        drop(shadow_tx);
        for d in drainers {
            let _ = d.join();
        }
        let tally = *shadow.tally.lock().expect("shadow tally poisoned");
        tally
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::activation::Activation;
    use crate::nn::network::{Network, NetworkConfig};
    use crate::publish::ModelParts;
    use crate::sampling::{Method, SamplerConfig};
    use crate::serve::pool::PoolConfig;
    use crate::serve::snapshot::ModelSnapshot;
    use crate::util::rng::Pcg64;

    fn parts(seed: u64) -> ModelParts {
        let cfg = NetworkConfig { n_in: 8, hidden: vec![24], n_out: 3, act: Activation::ReLU };
        let net = Network::new(&cfg, &mut Pcg64::seeded(seed));
        ModelParts::from_snapshot(ModelSnapshot::without_tables(
            net,
            SamplerConfig::with_method(Method::Lsh, 0.25),
            seed,
        ))
    }

    fn two_model_fleet() -> (Arc<ModelRegistry>, Router) {
        let reg = Arc::new(ModelRegistry::new());
        reg.register_frozen("a", parts(1), PoolConfig::default()).unwrap();
        reg.register_frozen("b", parts(2), PoolConfig::default()).unwrap();
        let router = Router::new(Arc::clone(&reg));
        (reg, router)
    }

    fn x(i: u64) -> Vec<f32> {
        (0..8).map(|j| ((i * 8 + j) as f32 * 0.17).sin()).collect()
    }

    #[test]
    fn exact_policy_routes_by_name_and_rejects_unknown() {
        let (reg, router) = two_model_fleet();
        let (tx, rx) = channel();
        let out = router.route(RoutedRequest { id: 0, model: "a".into(), x: x(0) }, &tx);
        assert_eq!(out, RouteOutcome::Enqueued { model: "a".into() });
        assert_eq!(rx.recv().unwrap().id, 0);
        let out = router.route(RoutedRequest { id: 1, model: "nope".into(), x: x(1) }, &tx);
        assert_eq!(out, RouteOutcome::UnknownModel);
        let stats = router.stats();
        assert_eq!(stats.model("a").unwrap().accepted, 1);
        assert_eq!(stats.model("b").unwrap().accepted, 0);
        reg.shutdown_all();
        router.shutdown();
    }

    #[test]
    fn canary_policy_splits_only_primary_traffic() {
        let (reg, router) = two_model_fleet();
        router.set_policy(RoutePolicy::Canary {
            primary: "a".into(),
            canary: "b".into(),
            canary_fraction: 0.5,
        });
        let (tx, rx) = channel();
        let n = 400u64;
        let mut to_canary = 0u64;
        for id in 0..n {
            match router.route(RoutedRequest { id, model: "a".into(), x: x(id) }, &tx) {
                RouteOutcome::Enqueued { model } => to_canary += u64::from(model == "b"),
                other => panic!("unexpected {other:?}"),
            }
        }
        // Requests naming the canary directly stay exact.
        let out = router.route(RoutedRequest { id: n, model: "b".into(), x: x(n) }, &tx);
        assert_eq!(out, RouteOutcome::Enqueued { model: "b".into() });
        drop(tx);
        assert_eq!(rx.iter().count() as u64, n + 1, "every admitted request answered");
        assert!(
            (to_canary as f64 / n as f64 - 0.5).abs() < 0.15,
            "50% split, saw {to_canary}/{n}"
        );
        // The split is the pure hash function — verify against it.
        let expected: u64 =
            (0..n).filter(|&id| canary_assignment(id, 0.5)).count() as u64;
        assert_eq!(to_canary, expected, "assignment must be the deterministic hash");
        reg.shutdown_all();
        router.shutdown();
    }

    #[test]
    fn deregistration_yields_unknown_via_route_and_closed_via_held_handles() {
        use crate::serve::pool::SubmitOutcome;

        let (reg, router) = two_model_fleet();
        // Hold the entry (as a mid-route lookup would) so its handle
        // outlives deregistration.
        let held = reg.get("a").unwrap();
        let (tx, _rx) = channel();
        reg.deregister("a").unwrap();
        // New routes can no longer resolve the name at all...
        let out = router.route(RoutedRequest { id: 0, model: "a".into(), x: x(0) }, &tx);
        assert_eq!(out, RouteOutcome::UnknownModel, "deregistered = unknown");
        // ...while a submission racing through an already-resolved entry
        // sees the closed queue — the SubmitOutcome route() maps to
        // RouteOutcome::Closed.
        assert_eq!(
            held.handle().try_submit(1, x(1), false, tx.clone()),
            SubmitOutcome::Closed,
            "held handle must report the closed pool, not enqueue into the void"
        );
        reg.shutdown_all();
        router.shutdown();
    }

    #[test]
    fn shadow_policy_discards_shadow_responses_and_tallies() {
        let reg = Arc::new(ModelRegistry::new());
        // Identical parts: divergence must be exactly zero.
        reg.register_frozen("prim", parts(9), PoolConfig::default()).unwrap();
        reg.register_frozen("shad", parts(9), PoolConfig::default()).unwrap();
        let router = Router::new(Arc::clone(&reg));
        router.set_policy(RoutePolicy::Shadow {
            primary: "prim".into(),
            shadow: "shad".into(),
            shadow_fraction: 1.0,
        });
        let (tx, rx) = channel();
        let n = 50u64;
        for id in 0..n {
            let out = router.route(RoutedRequest { id, model: "prim".into(), x: x(id) }, &tx);
            assert_eq!(out, RouteOutcome::Enqueued { model: "prim".into() });
            let resp = rx.recv().expect("primary response relayed to client");
            assert_eq!(resp.id, id);
            assert!(resp.logits.is_none(), "relay strips the divergence logits");
        }
        reg.shutdown_all();
        let tally = router.shutdown();
        assert_eq!(tally.sampled, n, "fraction 1.0 mirrors every request");
        assert_eq!(tally.compared, n, "every pair compared");
        assert_eq!(tally.pred_mismatches, 0);
        assert_eq!(tally.max_abs_logit_diff, 0.0, "identical snapshots diverge by nothing");
        assert_eq!(tally.shadow_shed, 0);
        assert_eq!(tally.unpaired, 0);
    }

    #[test]
    fn sampled_shadow_mirrors_only_the_deterministic_subset() {
        use crate::router::policy::shadow_assignment;

        let reg = Arc::new(ModelRegistry::new());
        reg.register_frozen("prim", parts(9), PoolConfig::default()).unwrap();
        reg.register_frozen("shad", parts(9), PoolConfig::default()).unwrap();
        let router = Router::new(Arc::clone(&reg));
        let fraction = 0.3;
        router.set_policy(RoutePolicy::Shadow {
            primary: "prim".into(),
            shadow: "shad".into(),
            shadow_fraction: fraction,
        });
        let (tx, rx) = channel();
        let n = 200u64;
        for id in 0..n {
            let out = router.route(RoutedRequest { id, model: "prim".into(), x: x(id) }, &tx);
            assert_eq!(out, RouteOutcome::Enqueued { model: "prim".into() });
            // Every client gets its primary answer, sampled or not.
            assert_eq!(rx.recv().expect("primary answer").id, id);
        }
        let expected: u64 = (0..n).filter(|&id| shadow_assignment(id, fraction)).count() as u64;
        let final_stats = reg.shutdown_all();
        let tally = router.shutdown();
        assert_eq!(tally.sampled, expected, "sample must be the pure id hash");
        assert_eq!(tally.compared, expected, "only sampled requests are compared");
        assert!(expected < n, "a 30% sample must not mirror everything");
        assert_eq!(tally.pred_mismatches, 0);
        assert_eq!(tally.unpaired, 0);
        // The shadow pool only saw the sampled subset.
        let shad_served = final_stats
            .iter()
            .find(|(name, _)| name == "shad")
            .map(|(_, s)| s.requests)
            .expect("shadow pool stats");
        assert_eq!(shad_served, expected);
    }
}
