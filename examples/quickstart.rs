//! Quickstart: train a hash-sampled network on a synthetic benchmark in
//! ~30 lines of API.
//!
//!   cargo run --release --example quickstart

use hashdl::data::synth::Benchmark;
use hashdl::nn::network::{Network, NetworkConfig};
use hashdl::optim::OptimConfig;
use hashdl::sampling::{Method, SamplerConfig};
use hashdl::train::trainer::{TrainConfig, Trainer};
use hashdl::util::rng::Pcg64;

fn main() {
    // 1. Data: procedural RECTANGLES benchmark (tall vs wide).
    let (train, test) = Benchmark::Rectangles.generate(2_000, 500, 42);

    // 2. Model: 784 -> 256 -> 256 -> 2, ReLU.
    let net = Network::new(
        &NetworkConfig { n_in: 784, hidden: vec![256, 256], n_out: 2, ..NetworkConfig::paper(784, 2, 2) },
        &mut Pcg64::seeded(42),
    );
    println!("{} parameters", net.n_params());

    // 3. Train with the paper's method: LSH-sampled active sets at 10%,
    //    minibatched so hashing and table maintenance amortize per batch.
    let mut trainer = Trainer::new(
        net,
        TrainConfig {
            epochs: 5,
            batch_size: 16,
            sampler: SamplerConfig::with_method(Method::Lsh, 0.10),
            optim: OptimConfig { lr: 1e-2, ..Default::default() },
            verbose: true,
            ..Default::default()
        },
    );
    let record = trainer.run(&train, &test);

    // 4. Results: accuracy and the paper's sustainability metric.
    println!(
        "\nfinal accuracy {:.3} using {:.1}% of hidden nodes and {:.2e} multiplications",
        record.final_acc(),
        100.0 * record.mean_active_fraction(),
        record.total_mults() as f64,
    );
}
