//! Serving quickstart: train a small LSH model, freeze it into a
//! snapshot, then serve a closed-loop request stream through the
//! multi-threaded micro-batching pool in dense and sparse modes.
//!
//!   cargo run --release --example serve_bench

use hashdl::prelude::*;
use hashdl::serve::bench::{mult_fraction, run_closed_loop, throughput_scaling, BenchConfig};
use std::time::Duration;

fn main() {
    // 1. Train a compact LSH network on the procedural MNIST stand-in.
    let (train, test) = Benchmark::Mnist8m.generate(2_000, 500, 42);
    let net = Network::new(
        &NetworkConfig { n_in: 784, hidden: vec![512, 512], n_out: 10, act: Activation::ReLU },
        &mut Pcg64::seeded(42),
    );
    let mut trainer = Trainer::new(
        net,
        TrainConfig {
            epochs: 2,
            batch_size: 32,
            sampler: SamplerConfig::with_method(Method::Lsh, 0.05),
            eval_cap: 300,
            ..Default::default()
        },
    );
    let record = trainer.run(&train, &test);
    println!("trained: accuracy {:.3}", record.final_acc());

    // 2. Freeze: weights + the live LSH tables become one snapshot. (In a
    //    real deployment this goes through serve::save_snapshot /
    //    load_snapshot — replicas loading the file serve identical answers.)
    let snapshot = trainer.snapshot();
    let engine = SparseInferenceEngine::from_snapshot(snapshot);
    let dense_budget = engine.dense_mults_per_request();

    // 3. Serve the test set closed-loop: dense baseline vs sparse, 1 and 4
    //    workers, micro-batches closed at 32 requests or 200us.
    let mut results = Vec::new();
    for sparse in [false, true] {
        for workers in [1usize, 4] {
            let cfg = BenchConfig {
                pool: PoolConfig {
                    workers,
                    max_batch: 32,
                    batch_deadline: Duration::from_micros(200),
                    queue_cap: 1024,
                    sparse,
                },
                clients: 0, // 2x workers
                requests: 1_000,
            };
            let r = run_closed_loop(&engine, &test.xs, &test.ys, &cfg);
            println!(
                "{:>6} w={} {:>8.0} req/s  p50 {:>5}us p99 {:>6}us  \
                 {:>5.1}% of dense mults  acc {:.3}",
                r.mode,
                r.workers,
                r.requests_per_sec,
                r.p50_micros,
                r.p99_micros,
                100.0 * r.mults_per_request / dense_budget as f64,
                r.accuracy,
            );
            results.push(r);
        }
    }
    println!(
        "sparse mult fraction {:.3}; scaling 1→4 workers: dense {:.2}x, sparse {:.2}x",
        mult_fraction(&results, dense_budget),
        throughput_scaling(&results, "dense"),
        throughput_scaling(&results, "sparse"),
    );
}
