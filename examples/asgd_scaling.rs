//! Hogwild ASGD scalability demo (paper §6.3, Figs 6+8 on one dataset):
//! convergence invariance across thread counts plus measured active-set
//! overlap and the conflict-model speedup projection.
//!
//!   cargo run --release --example asgd_scaling [-- --threads 1,2,4,8]

use hashdl::coordinator::experiment::model_speedup;
use hashdl::data::synth::Benchmark;
use hashdl::nn::network::{Network, NetworkConfig};
use hashdl::optim::OptimConfig;
use hashdl::sampling::{Method, SamplerConfig};
use hashdl::train::asgd::{run_asgd, AsgdConfig};
use hashdl::util::argparse::Parser;
use hashdl::util::rng::Pcg64;

fn main() {
    let p = Parser::new("asgd_scaling", "Hogwild thread-scaling demo")
        .opt("dataset", "rectangles", "benchmark name")
        .opt("threads", "1,2,4,8", "thread counts")
        .opt("epochs", "4", "epochs per run")
        .opt("train", "3000", "training samples")
        .opt("hidden", "256", "hidden width")
        .opt("batch-size", "16", "minibatch size per worker step")
        .opt("sparsity", "0.05", "LSH active fraction");
    let a = p.parse();
    let b = Benchmark::parse(a.get_or("dataset", "rectangles")).unwrap();
    let (train, test) = b.generate(a.parse_or("train", 3000usize), 500, 42);
    let hidden = a.parse_or("hidden", 256usize);
    let sparsity = a.parse_or("sparsity", 0.05f32);

    println!("threads,final_acc,secs_per_epoch,mean_overlap,model_speedup@56");
    for t in a.list("threads").iter().map(|s| s.parse::<usize>().unwrap_or(1)) {
        let net = Network::new(
            &NetworkConfig {
                n_in: b.dim(),
                hidden: vec![hidden; 3],
                n_out: b.n_classes(),
                ..NetworkConfig::paper(b.dim(), b.n_classes(), 3)
            },
            &mut Pcg64::seeded(42),
        );
        let out = run_asgd(
            net,
            &train,
            &test,
            &AsgdConfig {
                threads: t,
                epochs: a.parse_or("epochs", 4usize),
                batch_size: a.parse_or("batch-size", 16usize).max(1),
                sampler: SamplerConfig::lsh_tuned(sparsity),
                optim: OptimConfig { lr: 1e-2, ..Default::default() },
                conflict_sample_every: 10,
                eval_cap: 500,
                ..Default::default()
            },
        );
        let spe = out.record.total_secs() / out.record.epochs.len() as f64;
        println!(
            "{t},{:.4},{spe:.2},{:.4},{:.1}",
            out.record.final_acc(),
            out.conflicts.mean_overlap,
            model_speedup(56, out.conflicts.mean_overlap, 0.005),
        );
    }
    println!(
        "\nNote: this container has {} core(s); measured wall-clock speedup is\n\
         bounded by hardware. Convergence invariance + the overlap-driven model\n\
         (DESIGN.md §3) reproduce the paper's Fig 6/8 shapes.",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
}
