//! END-TO-END DRIVER (DESIGN.md deliverable): train the paper's full
//! architecture — 784-1000-1000-1000-10, ≈2.8M parameters — with LSH-5%
//! active sets on the synthetic MNIST8M benchmark, logging the loss curve
//! and comparing against the dense standard network on the same data.
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//!   cargo run --release --example e2e_train [-- --epochs 8 --train 20000]

use hashdl::data::synth::Benchmark;
use hashdl::nn::network::{Network, NetworkConfig};
use hashdl::optim::OptimConfig;
use hashdl::sampling::{Method, SamplerConfig};
use hashdl::train::trainer::{TrainConfig, Trainer};
use hashdl::util::argparse::Parser;
use hashdl::util::rng::Pcg64;

fn main() {
    let p = Parser::new("e2e_train", "paper-architecture end-to-end training")
        .opt("epochs", "8", "training epochs")
        .opt("train", "20000", "training samples")
        .opt("test", "2000", "test samples")
        .opt("sparsity", "0.05", "LSH active fraction")
        .opt("batch-size", "32", "minibatch size (1 = per-example Algorithm 1)")
        .opt("lr", "0.01", "learning rate")
        .opt("seed", "42", "seed")
        .flag("with-dense", "also train the dense standard baseline");
    let a = p.parse();

    let n_train = a.parse_or("train", 20_000usize);
    let n_test = a.parse_or("test", 2_000usize);
    let seed = a.parse_or("seed", 42u64);
    eprintln!("generating {n_train}+{n_test} synthetic MNIST8M samples...");
    let (train, test) = Benchmark::Mnist8m.generate(n_train, n_test, seed);

    // The paper's architecture: 3 hidden layers x 1000 nodes.
    let cfg = NetworkConfig::paper(784, 10, 3);
    let net = Network::new(&cfg, &mut Pcg64::seeded(seed));
    println!(
        "architecture 784-1000-1000-1000-10 | {} parameters | dense fwd: {} mults/example",
        net.n_params(),
        net.dense_mults_per_example()
    );

    let sparsity = a.parse_or("sparsity", 0.05f32);
    let batch_size = a.parse_or("batch-size", 32usize).max(1);
    println!("minibatch size {batch_size} (LSH selection + table maintenance amortized per batch)");
    let mut trainer = Trainer::new(
        net,
        TrainConfig {
            epochs: a.parse_or("epochs", 8usize),
            batch_size,
            sampler: SamplerConfig::lsh_tuned(sparsity),
            optim: OptimConfig { lr: a.parse_or("lr", 0.01f32), ..Default::default() },
            seed,
            eval_cap: n_test,
            verbose: true,
        },
    );
    let rec = trainer.run(&train, &test);

    println!("\nepoch,train_loss,test_loss,test_acc,active_frac,mults,secs");
    for e in &rec.epochs {
        println!(
            "{},{:.4},{:.4},{:.4},{:.4},{:.3e},{:.1}",
            e.epoch,
            e.train_loss,
            e.test_loss,
            e.test_acc,
            e.active_fraction,
            e.mults.total() as f64,
            e.wall_secs
        );
    }
    let dense_budget =
        3 * trainer.net.dense_mults_per_example() * (rec.epochs.len() * train.len()) as u64;
    println!(
        "\nLSH-{:.0}%: final acc {:.4} | mult ratio vs dense {:.3} | {:.1}s total",
        100.0 * sparsity,
        rec.final_acc(),
        rec.total_mults() as f64 / dense_budget as f64,
        rec.total_secs()
    );

    if a.has("with-dense") {
        eprintln!("\ntraining dense standard baseline for comparison...");
        let net = Network::new(&cfg, &mut Pcg64::seeded(seed));
        let mut dense = Trainer::new(
            net,
            TrainConfig {
                epochs: a.parse_or("epochs", 8usize),
                batch_size,
                sampler: SamplerConfig::with_method(Method::Standard, 1.0),
                optim: OptimConfig { lr: a.parse_or("lr", 0.01f32), ..Default::default() },
                seed,
                eval_cap: n_test,
                verbose: true,
            },
        );
        let drec = dense.run(&train, &test);
        println!(
            "STD: final acc {:.4} | {:.3e} mults | {:.1}s total\nLSH/STD: acc delta {:+.4}, mults x{:.3}, time x{:.2}",
            drec.final_acc(),
            drec.total_mults() as f64,
            drec.total_secs(),
            rec.final_acc() - drec.final_acc(),
            rec.total_mults() as f64 / drec.total_mults() as f64,
            rec.total_secs() / drec.total_secs(),
        );
    }
}
