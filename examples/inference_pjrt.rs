//! Serving-path demo: train a model with the rust LSH trainer, then serve
//! dense batched inference through the AOT-compiled PJRT artifact (the
//! production inference path — python never runs). Reports agreement
//! between the native and PJRT paths plus batched latency/throughput.
//!
//! Requires `make artifacts`.
//!
//!   cargo run --release --example inference_pjrt

use hashdl::nn::activation::Activation;
use hashdl::nn::network::{Network, NetworkConfig};
use hashdl::optim::OptimConfig;
use hashdl::runtime::pjrt::{batch_literal, literal_to_f32s, matrix_literal, vec_literal};
use hashdl::runtime::{ArtifactSet, PjrtRuntime};
use hashdl::sampling::{Method, SamplerConfig};
use hashdl::train::trainer::{TrainConfig, Trainer};
use hashdl::util::rng::Pcg64;
use std::path::Path;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    let arts = ArtifactSet::resolve(dir, "tiny").map_err(|e| anyhow::anyhow!(e))?;

    // 1. Train a small LSH network matching the `tiny` artifact topology.
    let mut rng = Pcg64::seeded(7);
    let mut gen = |n: usize, rng: &mut Pcg64| {
        let mut ds = hashdl::data::Dataset::new("tiny-blobs", arts.input_dim, arts.n_classes);
        for i in 0..n {
            let y = (i % arts.n_classes) as u32;
            let c = y as f32 - 0.5;
            ds.push((0..arts.input_dim).map(|_| c + 0.4 * rng.gaussian()).collect(), y);
        }
        ds
    };
    let train = gen(2_000, &mut rng);
    let test = gen(512, &mut rng);

    let net = Network::new(
        &NetworkConfig {
            n_in: arts.input_dim,
            hidden: vec![arts.layer_dims[0].1; arts.layer_dims.len() - 1],
            n_out: arts.n_classes,
            act: Activation::ReLU,
        },
        &mut Pcg64::seeded(7),
    );
    let mut trainer = Trainer::new(
        net,
        TrainConfig {
            epochs: 5,
            sampler: SamplerConfig::with_method(Method::Lsh, 0.25),
            optim: OptimConfig { lr: 0.05, ..Default::default() },
            ..Default::default()
        },
    );
    let rec = trainer.run(&train, &test);
    println!("trained LSH-25% model: accuracy {:.3}", rec.final_acc());

    // 2. Load the PJRT inference artifact and upload the trained weights.
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let exe = rt.load(&arts.fwd_path)?;
    let eval_batch = hashdl::runtime::std_baseline::EVAL_BATCH;

    // 3. Serve the test set in batches; check agreement with native eval.
    let t0 = Instant::now();
    let mut agree = 0usize;
    let mut correct = 0usize;
    let mut n = 0usize;
    for (cx, cy) in test.xs.chunks(eval_batch).zip(test.ys.chunks(eval_batch)) {
        let rows: Vec<&[f32]> = cx.iter().map(|v| v.as_slice()).collect();
        let mut args: Vec<xla::Literal> = Vec::new();
        for layer in &trainer.net.layers {
            args.push(matrix_literal(&layer.w)?);
            args.push(vec_literal(&layer.b));
        }
        args.push(batch_literal(&rows, eval_batch, arts.input_dim)?);
        let out = exe.run(&args)?;
        let logits = literal_to_f32s(&out[0])?;
        for (i, &y) in cy.iter().enumerate() {
            let row = &logits[i * arts.n_classes..(i + 1) * arts.n_classes];
            let pred = hashdl::tensor::vecops::argmax(row) as u32;
            agree += (pred == trainer.net.predict(&cx[i])) as usize;
            correct += (pred == y) as usize;
            n += 1;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "PJRT inference: {} samples in {:.1}ms ({:.0} samples/s) | accuracy {:.3} | native/PJRT agreement {:.1}%",
        n,
        secs * 1e3,
        n as f64 / secs,
        correct as f32 / n as f32,
        100.0 * agree as f32 / n as f32
    );
    assert_eq!(agree, n, "PJRT and native predictions must agree");
    Ok(())
}
