//! Sustainability sweep (paper §6.2, Figs 4/5 on one dataset): accuracy
//! vs active-node fraction for all five methods, with multiplication
//! ratios — "how much computation can we remove without losing accuracy?"
//!
//!   cargo run --release --example sustainability [-- --dataset convex --scale quick]

use hashdl::coordinator::experiment::{fig45, ExperimentScale, SPARSITY_GRID};
use hashdl::data::synth::Benchmark;
use hashdl::sampling::Method;
use hashdl::util::argparse::Parser;

fn main() {
    let p = Parser::new("sustainability", "accuracy vs computation sweep")
        .opt("dataset", "rectangles", "benchmark (mnist|norb|convex|rectangles)")
        .opt("scale", "quick", "quick|medium|paper")
        .opt("depth", "2", "hidden layers");
    let a = p.parse();
    let b = Benchmark::parse(a.get_or("dataset", "rectangles")).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let scale = ExperimentScale::parse(a.get_or("scale", "quick")).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let depth = a.parse_or("depth", 2usize);

    let report = fig45(
        &[b],
        &[Method::Standard, Method::Dropout, Method::AdaptiveDropout, Method::Wta, Method::Lsh],
        &[depth],
        &SPARSITY_GRID,
        &scale,
        false,
    );
    report.emit(None);

    // Headline: best LSH row at 5% vs the standard baseline.
    let std_acc = report
        .rows
        .iter()
        .find(|r| r[2] == "NN")
        .map(|r| r[4].clone())
        .unwrap_or_default();
    let lsh5 = report
        .rows
        .iter()
        .find(|r| r[2] == "LSH" && r[3] == "0.05")
        .map(|r| (r[4].clone(), r[5].clone()))
        .unwrap_or_default();
    println!(
        "standard accuracy {std_acc} | LSH at 5% active: accuracy {} using {}x of dense multiplications",
        lsh5.0, lsh5.1
    );
}
