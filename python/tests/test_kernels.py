"""Kernel-vs-reference correctness — the core L1 signal.

Hypothesis sweeps shapes (and implicitly tile boundaries) for both Pallas
kernels against the pure-jnp oracles in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dense import dense_layer, dense_vmem_estimate_bytes, matmul
from compile.kernels.simhash import simhash, vmem_estimate_bytes

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# simhash
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 48),
    d=st.integers(2, 96),
    k=st.integers(1, 8),
    l=st.integers(1, 6),
)
def test_simhash_matches_ref(b, d, k, l):
    x = rand(b * 7 + d, b, d)
    proj = rand(k * 13 + l, k * l, d)
    got = simhash(x, proj, k=k, l=l)
    want = ref.simhash_ref(x, proj, k, l)
    assert got.shape == (b, l)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_simhash_fingerprints_fit_k_bits():
    x = rand(1, 40, 32)
    proj = rand(2, 30, 32)
    fps = np.asarray(simhash(x, proj, k=6, l=5))
    assert fps.min() >= 0
    assert fps.max() < 2**6


def test_simhash_scale_invariance():
    # sign(p.(cx)) == sign(p.x) for c > 0 — same property the rust SRP test checks.
    x = rand(3, 8, 16)
    proj = rand(4, 12, 16)
    a = simhash(x, proj, k=4, l=3)
    b = simhash(x * 7.5, proj, k=4, l=3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_simhash_paper_settings_vmem_fits():
    # K=6, L=5, D=2048 (NORB), bt=32: the panel must fit typical 16 MB VMEM.
    assert vmem_estimate_bytes(2049, 6, 5, 32) < 16 * 2**20


# ---------------------------------------------------------------------------
# dense / matmul
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 40),
    d=st.integers(1, 64),
    n=st.integers(1, 48),
    act=st.sampled_from(["relu", "linear"]),
)
def test_dense_matches_ref(b, d, n, act):
    x = rand(b + d, b, d)
    w = rand(n + d + 1, n, d)
    bias = rand(n + 2, n)
    got = dense_layer(x, w, bias, act)
    want = ref.dense_ref(x, w, bias, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 40), k=st.integers(1, 48), n=st.integers(1, 40))
def test_matmul_matches_ref(m, k, n):
    a = rand(m + k, m, k)
    b = rand(n + k + 3, k, n)
    np.testing.assert_allclose(
        np.asarray(matmul(a, b)), np.asarray(ref.matmul_ref(a, b)), rtol=1e-5, atol=1e-5
    )


def test_dense_gradients_match_jnp_autodiff():
    # The custom VJP (Pallas backward matmuls) must agree with plain jnp grad.
    x = rand(1, 8, 12)
    w = rand(2, 10, 12)
    b = rand(3, 10)

    def loss_pallas(x, w, b):
        return (dense_layer(x, w, b, "relu") ** 2).sum()

    def loss_ref(x, w, b):
        return (ref.dense_ref(x, w, b, "relu") ** 2).sum()

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, bb in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-4, atol=1e-4)


def test_dense_relu_kills_negative_gradients():
    x = -jnp.ones((4, 6))
    w = jnp.ones((5, 6))
    b = jnp.zeros((5,))
    g = jax.grad(lambda w: dense_layer(x, w, b, "relu").sum())(w)
    np.testing.assert_array_equal(np.asarray(g), np.zeros_like(g))


def test_dense_rejects_unknown_activation():
    x, w, b = rand(1, 2, 3), rand(2, 4, 3), rand(3, 4)
    with pytest.raises(ValueError):
        dense_layer(x, w, b, "swish")


def test_dense_vmem_estimate_reasonable():
    # 1000-wide layer, D=2048 stripe at default tiles stays under VMEM.
    assert dense_vmem_estimate_bytes(2048) < 16 * 2**20
