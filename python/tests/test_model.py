"""L2 model checks: shapes, gradient descent actually descends, the fused
train_step artifact function is consistent with loss_fn, and every variant
lowers to HLO text."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def tiny_setup(seed=0, batch=8):
    input_dim, n_classes, hidden, depth = model.VARIANTS["tiny"]
    params = model.init_params(jax.random.PRNGKey(seed), input_dim, n_classes, hidden, depth)
    key = jax.random.PRNGKey(seed + 1)
    x = jax.random.normal(key, (batch, input_dim), jnp.float32)
    y = jax.random.randint(jax.random.PRNGKey(seed + 2), (batch,), 0, n_classes)
    return params, x, y


def test_forward_shapes_and_matches_ref():
    params, x, _ = tiny_setup()
    logits = model.forward(params, x)
    assert logits.shape == (8, 2)
    pairs = [(params[2 * i], params[2 * i + 1]) for i in range(len(params) // 2)]
    want = ref.mlp_ref(pairs, x)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_loss_positive_and_finite():
    params, x, y = tiny_setup()
    loss = model.loss_fn(params, x, y)
    assert np.isfinite(float(loss))
    assert float(loss) > 0.0


def test_train_step_descends():
    params, x, y = tiny_setup()
    loss0 = float(model.loss_fn(params, x, y))
    out = model.train_step(params, x, y, jnp.float32(0.1))
    loss_ret, new_params = float(out[0]), list(out[1:])
    assert abs(loss_ret - loss0) < 1e-5, "step returns the pre-update loss"
    loss1 = float(model.loss_fn(new_params, x, y))
    assert loss1 < loss0, f"SGD must reduce loss on the same batch: {loss0} -> {loss1}"


def test_train_step_preserves_shapes():
    params, x, y = tiny_setup()
    out = model.train_step(params, x, y, jnp.float32(0.01))
    assert len(out) == 1 + len(params)
    for p, q in zip(params, out[1:]):
        assert p.shape == q.shape
        assert p.dtype == q.dtype


def test_accuracy_bounds():
    params, x, y = tiny_setup()
    acc = float(model.accuracy(params, x, y))
    assert 0.0 <= acc <= 1.0


def test_overfits_tiny_problem():
    # A few steps of SGD on one batch should push accuracy to 1.0 — the
    # end-to-end differentiation sanity check through the Pallas kernels.
    params, x, _ = tiny_setup(seed=3)
    y = (x[:, 0] > 0).astype(jnp.int32)
    step = jax.jit(model.train_step)
    for _ in range(120):
        out = step(params, x, y, jnp.float32(0.2))
        params = list(out[1:])
    assert float(model.accuracy(params, x, y)) == 1.0


def test_tiny_variant_lowers_to_hlo_text():
    arts = aot.lower_variant("tiny", *model.VARIANTS["tiny"])
    assert set(arts) == {"mlp_step_tiny", "mlp_fwd_tiny", "simhash_tiny"}
    for name, (fn, arg_specs) in arts.items():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert len(text) > 200, name


def test_manifest_line_format():
    arts = aot.lower_variant("tiny", *model.VARIANTS["tiny"])
    line = aot.manifest_line("mlp_fwd_tiny", arts["mlp_fwd_tiny"])
    assert line.startswith("mlp_fwd_tiny ")
    assert "float32" in line
