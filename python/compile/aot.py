"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` 0.1.6 crate binds) rejects; the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits per variant (fixed shapes; one compiled executable per variant):
  mlp_step_<name>.hlo.txt  — fused SGD minibatch step (loss, new params)
  mlp_fwd_<name>.hlo.txt   — eval-batch logits
  simhash_<name>.hlo.txt   — the L1 fingerprint kernel at that input dim
plus a manifest.txt describing every artifact's signature for the rust
artifact registry (runtime/mod.rs parses it).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.simhash import simhash

LSH_K = 6
LSH_L = 5
SIMHASH_BATCH = 16


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs(input_dim, n_classes, hidden, depth):
    out = []
    for n_in, n_out in model.layer_dims(input_dim, n_classes, hidden, depth):
        out += [spec((n_out, n_in)), spec((n_out,))]
    return out


def lower_variant(name, input_dim, n_classes, hidden, depth):
    """Lower the three artifacts for one dataset variant."""
    psp = param_specs(input_dim, n_classes, hidden, depth)

    def step(*args):
        params = list(args[: len(psp)])
        x, y, lr = args[len(psp)], args[len(psp) + 1], args[len(psp) + 2]
        return model.train_step(params, x, y, lr)

    def fwd(*args):
        params = list(args[: len(psp)])
        return model.predict(params, args[len(psp)])

    step_args = psp + [
        spec((model.STEP_BATCH, input_dim)),
        spec((model.STEP_BATCH,), jnp.int32),
        spec((), jnp.float32),
    ]
    fwd_args = psp + [spec((model.EVAL_BATCH, input_dim))]
    sim_args = [
        spec((SIMHASH_BATCH, input_dim)),
        spec((LSH_K * LSH_L, input_dim)),
    ]

    def sim(x, proj):
        return (simhash(x, proj, k=LSH_K, l=LSH_L),)

    artifacts = {
        f"mlp_step_{name}": (step, step_args),
        f"mlp_fwd_{name}": (fwd, fwd_args),
        f"simhash_{name}": (sim, sim_args),
    }
    return artifacts


def manifest_line(name, fn_args):
    _, args = fn_args
    sig = ";".join(
        f"{'x'.join(str(d) for d in a.shape) if a.shape else 'scalar'}:{a.dtype}"
        for a in args
    )
    return f"{name} {sig}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default="tiny,mnist,norb,convex,rectangles",
        help="comma-separated subset of variants to lower",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    wanted = [v.strip() for v in args.variants.split(",") if v.strip()]
    manifest = []
    for name in wanted:
        input_dim, n_classes, hidden, depth = model.VARIANTS[name]
        artifacts = lower_variant(name, input_dim, n_classes, hidden, depth)
        for art_name, (fn, arg_specs) in artifacts.items():
            lowered = jax.jit(fn).lower(*arg_specs)
            text = to_hlo_text(lowered)
            path = os.path.join(args.out_dir, f"{art_name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest.append(manifest_line(art_name, (fn, arg_specs)))
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
