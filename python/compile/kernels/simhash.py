"""L1 Pallas kernel: signed-random-projection (SimHash) fingerprints.

The paper's per-query hashing cost (§5.5: "K x L hashes of the input") as
a single fused kernel: a (B, D) x (D, K*L) projection on the MXU followed
by sign extraction and K-bit packing on the VPU, emitting (B, L) int32
fingerprints.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the batch dimension is
tiled via BlockSpec so each grid step holds a (bt, D) input tile plus the
full (K*L, D) projection panel in VMEM. For the paper's settings
(K=6, L=5, D<=2049) the panel is ~240 KB fp32 — comfortably VMEM-resident
— so the kernel is a single-pass streaming matmul with no K-dim loop.
interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _simhash_kernel(k, l, x_ref, proj_ref, out_ref):
    """One batch tile: project, sign, pack K bits per table (MSB first)."""
    x = x_ref[...]                       # (bt, D)
    proj = proj_ref[...]                 # (K*L, D)
    # MXU: one (bt, D) @ (D, K*L) matmul.
    z = jax.lax.dot_general(
        x, proj, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                    # (bt, K*L)
    bits = (z >= 0.0).astype(jnp.int32)
    bits = bits.reshape(x.shape[0], l, k)
    # MSB-first bit weights built from iota *inside* the kernel (pallas
    # forbids captured constant arrays).
    iota = jax.lax.broadcasted_iota(jnp.int32, (l, k), dimension=1)
    weights = jnp.left_shift(jnp.int32(1), (k - 1) - iota)
    out_ref[...] = (bits * weights[None, :, :]).sum(axis=-1).astype(jnp.int32)


def _pick_tile(n, cap):
    """Largest divisor of n that is <= cap (grid shapes must divide)."""
    for t in range(min(n, cap), 0, -1):
        if n % t == 0:
            return t
    return 1


@functools.partial(jax.jit, static_argnames=("k", "l", "batch_tile"))
def simhash(x, proj, *, k, l, batch_tile=32):
    """Fingerprint a batch: x (B, D), proj (K*L, D) -> (B, L) int32."""
    b, d = x.shape
    assert proj.shape == (k * l, d), (proj.shape, (k * l, d))
    bt = _pick_tile(b, batch_tile)
    kernel = functools.partial(_simhash_kernel, k, l)
    return pl.pallas_call(
        kernel,
        grid=(b // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),       # stream batch tiles
            pl.BlockSpec((k * l, d), lambda i: (0, 0)),    # projection panel resident
        ],
        out_specs=pl.BlockSpec((bt, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l), jnp.int32),
        interpret=True,
    )(x, proj)


def vmem_estimate_bytes(d, k, l, batch_tile=32):
    """Analytic VMEM footprint of one grid step (see DESIGN.md §Perf)."""
    x_tile = batch_tile * d * 4
    panel = k * l * d * 4
    z = batch_tile * k * l * 4
    out = batch_tile * l * 4
    return x_tile + panel + z + out
