"""L1 Pallas kernels: fused fully-connected layer + generic matmul.

`dense_layer` is the forward hot-spot of the STD baseline (Figs 4/5/7):
activation(x @ w.T + b) computed tile-by-tile, with a custom VJP whose
backward matmuls run through the same Pallas `matmul` kernel, so the
entire L2 training step lowers to Pallas compute.

TPU adaptation: output is tiled (batch_tile x n_tile); each grid step
keeps an (bt, D) input stripe and an (nt, D) weight stripe in VMEM and
issues one MXU matmul — the BlockSpec schedule that replaces the paper's
CPU cache blocking. interpret=True for CPU-PJRT executability.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(n, cap):
    for t in range(min(n, cap), 0, -1):
        if n % t == 0:
            return t
    return 1


def _dense_kernel(activation, x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...]            # (bt, D)
    w = w_ref[...]            # (nt, D)
    b = b_ref[...]            # (nt,)
    z = (
        jax.lax.dot_general(
            x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        + b[None, :]
    )
    if activation == "relu":
        z = jnp.maximum(z, 0.0)
    o_ref[...] = z


def _dense_forward(x, w, b, activation, batch_tile, n_tile):
    if activation not in ("relu", "linear"):
        raise ValueError(f"unknown activation {activation!r}")
    bsz, d = x.shape
    n = w.shape[0]
    bt = _pick_tile(bsz, batch_tile)
    nt = _pick_tile(n, n_tile)
    kernel = functools.partial(_dense_kernel, activation)
    return pl.pallas_call(
        kernel,
        grid=(bsz // bt, n // nt),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((nt, d), lambda i, j: (j, 0)),
            pl.BlockSpec((nt,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, nt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.float32),
        interpret=True,
    )(x, w, b)


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def matmul(a, b, *, m_tile=64, n_tile=256):
    """Pallas (M,K)@(K,N) matmul, output-tiled, K resident per step."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    mt = _pick_tile(m, m_tile)
    nt = _pick_tile(n, n_tile)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // mt, n // nt),
        in_specs=[
            pl.BlockSpec((mt, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, nt), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((mt, nt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense_layer(x, w, b, activation="relu"):
    """activation(x @ w.T + b) with Pallas forward AND backward."""
    return _dense_forward(x, w, b, activation, batch_tile=32, n_tile=256)


def _dense_fwd(x, w, b, activation):
    a = _dense_forward(x, w, b, activation, batch_tile=32, n_tile=256)
    return a, (x, w, a)


def _dense_bwd(activation, res, g):
    x, w, a = res
    if activation == "relu":
        dz = g * (a > 0.0)
    elif activation == "linear":
        dz = g
    else:
        raise ValueError(f"unknown activation {activation!r}")
    dx = matmul(dz, w)                       # (B,N)@(N,D)
    dw = matmul(dz.T, x)                     # (N,B)@(B,D)
    db = dz.sum(axis=0)
    return dx, dw, db


dense_layer.defvjp(_dense_fwd, _dense_bwd)


def dense_vmem_estimate_bytes(d, batch_tile=32, n_tile=256):
    """Analytic VMEM per grid step (x stripe + w stripe + out tile)."""
    return batch_tile * d * 4 + n_tile * d * 4 + n_tile * 4 + batch_tile * n_tile * 4
