"""Pure-jnp reference oracles for the Pallas kernels.

These are the single source of truth for kernel correctness: pytest
compares every Pallas kernel against these under hypothesis-driven shape
sweeps (python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def simhash_ref(x, proj, k, l):
    """Signed-random-projection fingerprints, packed K bits per table.

    Args:
      x:    (B, D) query/data batch.
      proj: (K*L, D) gaussian projection directions; table j uses rows
            [j*K, (j+1)*K) — identical layout to the rust `SrpHash`.
      k, l: LSH parameters.

    Returns:
      (B, L) int32 fingerprints; bit i (MSB-first within K) is
      sign(proj[jK+i] . x), matching rust's `pack_bits`.
    """
    bits = (x @ proj.T >= 0.0).astype(jnp.int32)  # (B, K*L)
    bits = bits.reshape(x.shape[0], l, k)
    weights = 2 ** jnp.arange(k - 1, -1, -1, dtype=jnp.int32)  # MSB first
    return (bits * weights).sum(axis=-1).astype(jnp.int32)


def dense_ref(x, w, b, activation="relu"):
    """Fully-connected layer: activation(x @ w.T + b).

    w layout is (n_out, n_in) — row per output neuron, same as rust.
    """
    z = x @ w.T + b
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "linear":
        return z
    raise ValueError(f"unknown activation {activation!r}")


def matmul_ref(a, b):
    """Plain (M,K)@(K,N) matmul."""
    return a @ b


def mlp_ref(params, x, activation="relu"):
    """Forward pass through an MLP given [(w1,b1),...]; last layer linear."""
    h = x
    for i, (w, b) in enumerate(params):
        act = "linear" if i == len(params) - 1 else activation
        h = dense_ref(h, w, b, act)
    return h


def softmax_xent_ref(logits, labels):
    """Mean softmax cross-entropy."""
    logz = jnp.log(jnp.sum(jnp.exp(logits - logits.max(-1, keepdims=True)), -1))
    logp = logits - logits.max(-1, keepdims=True) - logz[..., None]
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
