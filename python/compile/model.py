"""L2: the dense MLP (the paper's STD baseline) built on the L1 Pallas
kernels, plus the fused training step that AOT-lowers to a single HLO
module per dataset variant.

Python here is build-time only: `aot.py` lowers these functions to
artifacts/*.hlo.txt once, and the rust coordinator executes them through
PJRT on the request path.
"""

import jax
import jax.numpy as jnp

from compile.kernels.dense import dense_layer

# ---------------------------------------------------------------------------
# Variants: one AOT artifact set per dataset (fixed shapes).
# ---------------------------------------------------------------------------

#: name -> (input_dim, n_classes, hidden_width, n_hidden_layers)
VARIANTS = {
    "mnist": (784, 10, 1000, 3),
    "norb": (2048, 5, 1000, 3),
    "convex": (784, 2, 1000, 3),
    "rectangles": (784, 2, 1000, 3),
    # small variant used by fast tests and the runtime round-trip check
    "tiny": (16, 2, 32, 2),
}

#: STD baseline minibatch (paper §6.3.3: "mini-batch of size 32").
STEP_BATCH = 32
#: Evaluation forward batch.
EVAL_BATCH = 256


def layer_dims(input_dim, n_classes, hidden, depth):
    """[(n_in, n_out)] per layer, paper architecture."""
    dims = [input_dim] + [hidden] * depth + [n_classes]
    return list(zip(dims[:-1], dims[1:]))


def init_params(key, input_dim, n_classes, hidden, depth):
    """Glorot-uniform params as a flat list [w1, b1, w2, b2, ...].

    w layout (n_out, n_in): row per neuron, matching rust.
    """
    params = []
    for n_in, n_out in layer_dims(input_dim, n_classes, hidden, depth):
        key, sub = jax.random.split(key)
        limit = (6.0 / (n_in + n_out)) ** 0.5
        w = jax.random.uniform(sub, (n_out, n_in), jnp.float32, -limit, limit)
        params += [w, jnp.zeros((n_out,), jnp.float32)]
    return params


def forward(params, x):
    """Logits for batch x. Hidden layers: Pallas fused relu; last: linear."""
    n_layers = len(params) // 2
    h = x
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        act = "linear" if i == n_layers - 1 else "relu"
        h = dense_layer(h, w, b, act)
    return h


def loss_fn(params, x, y):
    """Mean softmax cross-entropy."""
    logits = forward(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    logp = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0] - logz
    return -logp.mean()


def train_step(params, x, y, lr):
    """One fused SGD minibatch step: returns (loss, *new_params).

    This is the artifact the rust STD baseline executes per batch — loss
    and all parameter updates in one PJRT call, no python anywhere.
    """
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return (loss, *new_params)


def predict(params, x):
    """Eval-time logits (separate artifact with the eval batch size)."""
    return (forward(params, x),)


def accuracy(params, x, y):
    return (forward(params, x).argmax(-1) == y).mean()
